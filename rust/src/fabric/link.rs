//! Link technology models (Table 3, §6.1).
//!
//! A [`LinkSpec`] captures one directed physical link: class, per-direction
//! bandwidth, fixed per-hop latency (propagation + port logic), and the flit
//! framing that expands payload into wire bytes. The constants are the
//! paper's published figures:
//!
//! | Link | Unidirectional BW | Latency | Flit |
//! |---|---|---|---|
//! | CXL 3.0 x16 (PCIe 6.0) | 128 GB/s | 100–250 ns typical | 256 B PBR / 68 B HBR |
//! | CXL 2.0 x16 (PCIe 5.0) | 64 GB/s | 100–250 ns | 68 B |
//! | UALink 1.0 x4 | 100 GB/s | < 1 µs in-rack | 640 B |
//! | NVLink 5.0 x2 | 50 GB/s | < 500 ns in-rack | 48–272 B packets |
//! | NVLink C2C | 450 GB/s/dir (900 GB/s bidir) | ~90 ns | 272 B |
//! | PCIe Gen5 x16 | 64 GB/s | ~300 ns | 256 B TLP |
//! | Ethernet 800G | 100 GB/s | ~600 ns port-to-port | 9 KB jumbo |
//! | InfiniBand NDR x4 | 50 GB/s | ~130 ns switch, µs-scale e2e | 4 KB MTU |

use super::flit::FlitFormat;

/// Broad class of a link (drives coherence capability and reporting).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// CXL 1.0/1.1 point-to-point (no switching).
    Cxl1,
    /// CXL 2.0 (single-level switching, HBR).
    Cxl2,
    /// CXL 3.0+ (multi-level switching, PBR, back-invalidation).
    Cxl3,
    /// NVIDIA NVLink (5.0 unless stated).
    NvLink,
    /// NVLink chip-to-chip (Grace–Blackwell coherent link).
    NvLinkC2C,
    /// Ultra Accelerator Link 1.0.
    UaLink,
    /// Plain PCIe.
    Pcie,
    /// Ethernet scale-out fabric (RoCE capable).
    Ethernet,
    /// InfiniBand scale-out fabric.
    InfiniBand,
}

impl LinkClass {
    /// Does this link provide protocol-level (hardware) cache coherence?
    /// Table 3: CXL yes; UALink no; NVLink only via C2C.
    pub fn cache_coherent(self) -> bool {
        matches!(self, LinkClass::Cxl1 | LinkClass::Cxl2 | LinkClass::Cxl3 | LinkClass::NvLinkC2C)
    }

    /// Does the link support memory pooling beyond its own cluster?
    pub fn memory_pooling(self) -> bool {
        matches!(self, LinkClass::Cxl2 | LinkClass::Cxl3)
    }

    /// Is this a scale-out (long-distance, software-stack) fabric?
    pub fn scale_out(self) -> bool {
        matches!(self, LinkClass::Ethernet | LinkClass::InfiniBand)
    }
}

/// One directed link.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkSpec {
    /// Human-readable name for reports.
    pub name: &'static str,
    pub class: LinkClass,
    /// Bandwidth in bytes/ns (== GB/s), per direction.
    pub bw: f64,
    /// Fixed per-hop latency in ns (propagation + SerDes + port logic).
    pub latency: f64,
    /// Framing format.
    pub flit: FlitFormat,
}

impl LinkSpec {
    /// Time for the message body to stream over this link (ns).
    pub fn wire_time(&self, payload_bytes: u64) -> f64 {
        self.wire_bytes(payload_bytes) as f64 / self.bw
    }

    /// Wire bytes for a payload on this link.
    pub fn wire_bytes(&self, payload_bytes: u64) -> u64 {
        self.flit.wire_bytes(payload_bytes)
    }

    /// Per-hop fixed latency (ns).
    pub fn hop_latency(&self) -> f64 {
        self.latency
    }

    // ----- catalogue (Table 3 constants) ---------------------------------

    /// CXL 3.0 x16 over PCIe 6.0: 128 GB/s, PBR 256 B flits, ~120 ns port hop
    /// (paper: 100–250 ns typical end-to-end through one switch).
    pub fn cxl3_x16() -> LinkSpec {
        LinkSpec { name: "CXL3.0-x16", class: LinkClass::Cxl3, bw: 128.0, latency: 60.0, flit: FlitFormat::CXL_256B }
    }

    /// CXL 3.0 running in HBR mode (68 B flits, 32 GT/s → 64 GB/s).
    pub fn cxl3_hbr_x16() -> LinkSpec {
        LinkSpec { name: "CXL3.0-HBR-x16", class: LinkClass::Cxl3, bw: 64.0, latency: 60.0, flit: FlitFormat::CXL_68B }
    }

    /// CXL 2.0 x16 over PCIe 5.0: 64 GB/s, 68 B flits.
    pub fn cxl2_x16() -> LinkSpec {
        LinkSpec { name: "CXL2.0-x16", class: LinkClass::Cxl2, bw: 64.0, latency: 70.0, flit: FlitFormat::CXL_68B }
    }

    /// CXL 1.0/1.1 x16 direct endpoint attach.
    pub fn cxl1_x16() -> LinkSpec {
        LinkSpec { name: "CXL1.1-x16", class: LinkClass::Cxl1, bw: 64.0, latency: 80.0, flit: FlitFormat::CXL_68B }
    }

    /// Lightweight coherence-centric CXL (§6.3): protocol trimmed to
    /// CXL.cache only — shorter pipeline, lower hop latency.
    pub fn cxl_lightweight_coherence() -> LinkSpec {
        LinkSpec { name: "CXL-lite-coh", class: LinkClass::Cxl3, bw: 128.0, latency: 40.0, flit: FlitFormat::CXL_256B }
    }

    /// Capacity-oriented lightweight CXL (§6.3): CXL.mem-only tier-2 pool
    /// link; slightly higher latency budget, full bandwidth.
    pub fn cxl_lightweight_mem() -> LinkSpec {
        LinkSpec { name: "CXL-lite-mem", class: LinkClass::Cxl3, bw: 128.0, latency: 80.0, flit: FlitFormat::CXL_256B }
    }

    /// NVLink 5.0, one link (x2 lanes): 50 GB/s/dir.
    pub fn nvlink5() -> LinkSpec {
        LinkSpec { name: "NVLink5", class: LinkClass::NvLink, bw: 50.0, latency: 110.0, flit: FlitFormat::NVLINK_PACKET }
    }

    /// NVLink 5.0 full GPU port bundle (18 links = 900 GB/s/dir on Blackwell).
    pub fn nvlink5_bundle() -> LinkSpec {
        LinkSpec { name: "NVLink5-x18", class: LinkClass::NvLink, bw: 900.0, latency: 110.0, flit: FlitFormat::NVLINK_PACKET }
    }

    /// NVLink chip-to-chip (Grace<->Blackwell): 900 GB/s bidir = 450 GB/s/dir.
    pub fn nvlink_c2c() -> LinkSpec {
        LinkSpec { name: "NVLink-C2C", class: LinkClass::NvLinkC2C, bw: 450.0, latency: 90.0, flit: FlitFormat::NVLINK_PACKET }
    }

    /// UALink 1.0 x4 port: 100 GB/s/dir, 640 B flits.
    pub fn ualink1_x4() -> LinkSpec {
        LinkSpec { name: "UALink1-x4", class: LinkClass::UaLink, bw: 100.0, latency: 150.0, flit: FlitFormat::UALINK_640B }
    }

    /// PCIe Gen5 x16: 64 GB/s/dir.
    pub fn pcie5_x16() -> LinkSpec {
        LinkSpec { name: "PCIe5-x16", class: LinkClass::Pcie, bw: 64.0, latency: 150.0, flit: FlitFormat::PCIE_TLP }
    }

    /// PCIe Gen6 x16: 128 GB/s/dir.
    pub fn pcie6_x16() -> LinkSpec {
        LinkSpec { name: "PCIe6-x16", class: LinkClass::Pcie, bw: 128.0, latency: 140.0, flit: FlitFormat::PCIE_TLP }
    }

    /// 800G Ethernet port: 100 GB/s, jumbo frames. Port-to-port latency only;
    /// the software stack cost lives in [`super::netstack`].
    pub fn ethernet_800g() -> LinkSpec {
        LinkSpec { name: "Eth-800G", class: LinkClass::Ethernet, bw: 100.0, latency: 600.0, flit: FlitFormat::ETHERNET_JUMBO }
    }

    /// 400G Ethernet port: 50 GB/s.
    pub fn ethernet_400g() -> LinkSpec {
        LinkSpec { name: "Eth-400G", class: LinkClass::Ethernet, bw: 50.0, latency: 600.0, flit: FlitFormat::ETHERNET_JUMBO }
    }

    /// InfiniBand NDR x4: 400 Gb/s = 50 GB/s, cut-through switches (~130 ns
    /// per hop); end-to-end RDMA verbs cost modelled in `netstack`.
    pub fn infiniband_ndr() -> LinkSpec {
        LinkSpec { name: "IB-NDR", class: LinkClass::InfiniBand, bw: 50.0, latency: 130.0, flit: FlitFormat::INFINIBAND_4K }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_bandwidth_ordering() {
        // Table 3: CXL3 128 > UALink 100 > NVLink/link 50 GB/s.
        assert!(LinkSpec::cxl3_x16().bw > LinkSpec::ualink1_x4().bw);
        assert!(LinkSpec::ualink1_x4().bw > LinkSpec::nvlink5().bw);
    }

    #[test]
    fn table3_latency_ordering() {
        // CXL (100-250ns) < NVLink (<500ns) < UALink (<1us) < Ethernet.
        let cxl = LinkSpec::cxl3_x16().hop_latency();
        let nv = LinkSpec::nvlink5().hop_latency();
        let ua = LinkSpec::ualink1_x4().hop_latency();
        let eth = LinkSpec::ethernet_800g().hop_latency();
        assert!(cxl < nv && nv < ua && ua < eth);
    }

    #[test]
    fn coherence_matrix_matches_table3() {
        assert!(LinkClass::Cxl3.cache_coherent());
        assert!(LinkClass::Cxl1.cache_coherent());
        assert!(!LinkClass::UaLink.cache_coherent());
        assert!(!LinkClass::NvLink.cache_coherent());
        assert!(LinkClass::NvLinkC2C.cache_coherent());
        assert!(!LinkClass::Ethernet.cache_coherent());
    }

    #[test]
    fn pooling_only_on_switched_cxl() {
        assert!(!LinkClass::Cxl1.memory_pooling());
        assert!(LinkClass::Cxl2.memory_pooling());
        assert!(LinkClass::Cxl3.memory_pooling());
        assert!(!LinkClass::NvLink.memory_pooling());
    }

    #[test]
    fn wire_time_scales_with_bytes() {
        let l = LinkSpec::cxl3_x16();
        let t1 = l.wire_time(1 << 20);
        let t2 = l.wire_time(2 << 20);
        assert!((t2 / t1 - 2.0).abs() < 0.01);
    }

    #[test]
    fn gb_transfer_time_sane() {
        // 1 GB over 128 GB/s ~ 7.8-8.5 ms (framing adds ~6.7%).
        let l = LinkSpec::cxl3_x16();
        let t = l.wire_time(1_000_000_000);
        assert!(t > 7.5e6 && t < 9.0e6, "t={t}");
    }
}
