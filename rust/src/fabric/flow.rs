//! Flow-level, contention-aware fabric simulation on the event engine.
//!
//! The analytic [`super::Fabric`] prices a transfer with closed-form math
//! against per-edge `busy_until` scalars — adequate for back-to-back
//! traffic, but structurally blind to the paper's central object: the
//! *communication tax* that appears when concurrent flows share links.
//! [`FabricSim`] models it directly:
//!
//! * every [`Transfer`] is routed along a concrete edge path in the owned
//!   [`Topology`] (HBR fixed shortest path, or PBR spreading over the
//!   equal-cost set by live flow count);
//! * each directed edge is a shared fluid resource; active flows get
//!   **max-min fair** rates via progressive filling, weighted by each
//!   edge's flit-framing expansion so wire bytes (not payload bytes) are
//!   what saturates a link;
//! * the simulation is **event-driven at flow granularity**: rates only
//!   change when a flow starts or finishes, so we repair bottleneck rates
//!   at those instants and reschedule the next completion — no per-flit or
//!   per-quantum ticking.
//!
//! Five mechanisms keep the event cost sublinear in the active population
//! (the difference between simulating hundreds of flows and the open-loop
//! swarms the ROADMAP north-star demands):
//!
//! * **Incremental rate repair** ([`RateSolver::Incremental`], the
//!   default): a flow start/finish re-solves only the connected component
//!   of flows that *transitively* share links with the changed route. The
//!   max-min fair allocation is unique and decomposes over link-disjoint
//!   components, so the restricted solve returns exactly the global answer
//!   (float divergence is summation-order noise, orders of magnitude below
//!   the trace/completion granularity). A per-edge flow index makes the
//!   component walk O(component); when the dirty set exceeds a
//!   configurable fraction of the population the solver falls back to the
//!   residual global pass below. Per-flow progress and per-edge
//!   utilization are folded lazily — untouched flows carry
//!   `(delivered, rate, updated_at)` forward exactly because their rate
//!   did not change.
//! * **Same-timestamp admission batching**
//!   ([`AdmissionBatching::Coalesce`], the default): collective launches,
//!   DP fan-out, and colocation floods start hundreds of flows at one sim
//!   instant. Each start links into the active set immediately, but the
//!   rate solve is deferred to a single flush carrying the union of the
//!   batch's seed edges, scheduled at the *same* instant after every
//!   already-queued same-time event ([`Engine::defer`]). A completion
//!   batch at the same instant drains the pending seeds into its own
//!   solve, so rates are always repaired before any read or time advance.
//!   Zero sim time elapses between the deferred starts and the flush, so
//!   only the final rate assignment is observable — the batched solve
//!   leaves exactly the state the per-start solves would have.
//! * **Parallel residual solves**: every global pass ([`RateSolver::Global`],
//!   or the incremental fallback) enumerates *all* link-disjoint
//!   components of the active population in canonical order (ascending
//!   minimum flow id, via the same stamped BFS the incremental walk uses)
//!   and progressive-fills each component independently — max-min
//!   decomposes exactly over components. Components fan out over scoped
//!   worker threads ([`FabricSim::set_solver_threads`]; the default
//!   honors `RAYON_NUM_THREADS`, else the machine's parallelism) once the
//!   dirty population reaches [`FabricSim::set_parallel_solve_threshold`],
//!   each worker filling disjoint contiguous ranges of one shared
//!   scratch. The component enumeration, per-component arithmetic, and
//!   write-back order are all fixed independently of the worker count, so
//!   results are **byte-identical for every thread count**.
//! * **Same-route aggregation** ([`AggregationPolicy::SameRoute`], opt-in):
//!   concurrent same-`(src, dst, class)` transfers on the identical route
//!   fuse into one aggregate flow that counts with its member multiplicity
//!   in the max-min solve, so the fabric prices m members exactly as m
//!   separate flows while the solver handles one object. Members keep
//!   per-member completion thresholds on the aggregate's stream position,
//!   so finish times, ledger byte attribution, and completion callbacks
//!   are per-member and exact. This generalizes the collectives' static
//!   ring fusion ([`crate::workload::collectives::ring_rounds_flows_on`])
//!   to dynamic serving/KV/activation swarms whose concurrency is only
//!   discovered at run time.
//! * **Indexed completion heap** ([`super::minheap::FinishHeap`]): the
//!   next finish is an O(1) peek instead of an O(active) scan.
//!
//! A per-link **communication-tax ledger** (delivered payload bytes,
//! time-integrated utilization, peak concurrent flows, per-flow contention
//! delay) is maintained as the run advances and can be exported into
//! experiment reports and [`crate::coordinator::telemetry`].
//!
//! An *uncontended* flow completes in exactly `Σ hop_latency +
//! max_e wire_time_e(bytes)` — the same figure the analytic
//! [`crate::datacenter::hierarchy::CommPath::time`] produces for the
//! equivalent hardware-mediated path — so the flow model degrades to the
//! closed form when the fabric is idle, and everything above that baseline
//! is measured queueing/contention.
//!
//! Units follow the crate convention: time ns (`f64`), sizes bytes,
//! bandwidth bytes/ns.

use super::link::LinkSpec;
use super::minheap::FinishHeap;
use super::routing::RoutingPolicy;
use super::topology::{NodeId, Topology};
use super::EdgeId;
use crate::sim::stats::TimeWeighted;
use crate::sim::{Engine, HookId, SimTime, Summary};
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Identifier of a flow within one [`FabricSim`] (submission order).
pub type FlowId = u64;

/// How rate repair responds to a flow start/finish.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RateSolver {
    /// Re-run progressive filling over every active flow on each change
    /// (the original behavior; `O(rounds × active × hops)` per event).
    Global,
    /// Re-solve only the link-sharing connected component of the changed
    /// flow — exactly equivalent to [`RateSolver::Global`] because max-min
    /// allocations decompose over link-disjoint components — falling back
    /// to the global pass when the dirty component exceeds
    /// `global_fraction` of the active population (past that point the
    /// component walk is pure overhead).
    Incremental {
        /// Dirty-set size (as a fraction of active flows) above which one
        /// global pass is cheaper than component bookkeeping. 0 forces
        /// global every time; 1 never falls back.
        global_fraction: f64,
    },
}

impl Default for RateSolver {
    fn default() -> Self {
        RateSolver::Incremental { global_fraction: 0.5 }
    }
}

/// Whether concurrent same-route transfers coalesce into aggregate flows.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AggregationPolicy {
    /// Every transfer is its own flow (the default — traces and ledgers
    /// are byte-for-byte those of the original engine).
    #[default]
    Off,
    /// Transfers with the same `(src, dst, class)` on the identical edge
    /// path join one aggregate flow while it is in flight. The aggregate
    /// counts with its member multiplicity in the max-min solve and each
    /// member keeps its own bytes, completion time, ledger attribution,
    /// and callback — the fabric arithmetic is unchanged, only the solver
    /// population shrinks. Within one completion batch, members of the
    /// same aggregate settle in stream (threshold) order.
    SameRoute,
}

/// Whether flow starts sharing one sim instant coalesce into a single
/// deferred rate solve.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AdmissionBatching {
    /// Every activation repairs rates on the spot (the original
    /// behavior; a k-flow collective launch pays k solves at one instant).
    Immediate,
    /// Activations sharing a timestamp link into the active set at once
    /// but defer the rate solve to one same-instant flush carrying the
    /// union of their seed edges (the default). Zero sim time elapses
    /// between the deferred starts and the flush, so the batched solve
    /// leaves exactly the state the per-start solves would have — only
    /// the k−1 intermediate (never-observable) rate assignments are
    /// skipped.
    #[default]
    Coalesce,
}

/// What a transfer carries — drives per-class ledger accounting so the
/// tax can be attributed (gradient sync vs KV fetch vs activation hop).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TrafficClass {
    /// Collective-communication step (all-reduce chunk, all-to-all shard).
    Collective,
    /// KV-cache movement between accelerator and pool.
    KvCache,
    /// Activation traffic (pipeline/tensor boundaries, prefill→decode).
    Activation,
    /// Parameter/weight movement (loads, rebalancing).
    Parameter,
    /// Small control/metadata messages.
    Control,
    /// Hierarchical-memory tier movement (demotion, promotion, placement
    /// migration) — the §6.3 traffic the tier model used to price analytically.
    Migration,
}

impl TrafficClass {
    /// Number of traffic classes (ledger column count).
    pub const COUNT: usize = 6;

    /// All classes, in ledger column order.
    pub const ALL: [TrafficClass; Self::COUNT] =
        [Self::Collective, Self::KvCache, Self::Activation, Self::Parameter, Self::Control, Self::Migration];

    /// Stable lowercase name for reports.
    pub fn name(self) -> &'static str {
        match self {
            Self::Collective => "collective",
            Self::KvCache => "kvcache",
            Self::Activation => "activation",
            Self::Parameter => "parameter",
            Self::Control => "control",
            Self::Migration => "migration",
        }
    }

    fn index(self) -> usize {
        match self {
            Self::Collective => 0,
            Self::KvCache => 1,
            Self::Activation => 2,
            Self::Parameter => 3,
            Self::Control => 4,
            Self::Migration => 5,
        }
    }
}

/// One transfer request.
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    pub src: NodeId,
    pub dst: NodeId,
    /// Payload bytes (wire expansion applied per edge from its flit format).
    pub bytes: u64,
    pub class: TrafficClass,
}

impl Transfer {
    /// Convenience constructor.
    pub fn new(src: NodeId, dst: NodeId, bytes: u64, class: TrafficClass) -> Self {
        Transfer { src, dst, bytes, class }
    }
}

/// Completion record handed to the submitter's callback.
#[derive(Clone, Copy, Debug)]
pub struct FlowDone {
    pub id: FlowId,
    pub class: TrafficClass,
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: u64,
    /// Submission time (ns).
    pub submitted: SimTime,
    /// Delivery time of the last byte (ns).
    pub arrival: SimTime,
    /// End-to-end latency: `arrival - submitted`.
    pub latency: f64,
    /// Uncontended latency over the same route (hop latencies + bottleneck
    /// wire time) — what the analytic model would have charged.
    pub ideal: f64,
    /// The communication tax on this flow: `latency - ideal` (>= 0 up to
    /// float rounding).
    pub contention: f64,
    /// Hops traversed.
    pub hops: usize,
}

/// Per-link row of the communication-tax ledger.
#[derive(Clone, Debug)]
pub struct LinkUse {
    pub edge: EdgeId,
    pub src: NodeId,
    pub dst: NodeId,
    /// Link technology name (from [`LinkSpec::name`]).
    pub link: &'static str,
    /// Payload bytes delivered across this edge.
    pub payload: u64,
    /// Time-weighted utilization in [0, 1] over the elapsed sim span.
    pub utilization: f64,
    /// Peak number of flows simultaneously routed over this edge.
    pub peak_flows: u32,
}

/// Aggregated communication-tax ledger for one simulation run.
#[derive(Clone, Debug)]
pub struct CommTaxLedger {
    /// Simulated span the utilization figures are normalized over (ns).
    pub elapsed: f64,
    /// Flows completed.
    pub flows: u64,
    /// Total payload bytes delivered.
    pub total_payload: u64,
    /// Payload bytes per traffic class (indexed per [`TrafficClass::ALL`]).
    pub class_payload: [u64; TrafficClass::COUNT],
    /// Every edge that carried traffic, in edge-id order.
    pub per_link: Vec<LinkUse>,
    /// Per-flow contention delay (`latency - ideal`) distribution.
    pub contention: Summary,
    /// Mean utilization over links that carried traffic.
    pub mean_utilization: f64,
    /// Highest per-link utilization.
    pub peak_utilization: f64,
    /// Mean and peak concurrent active flows over time.
    pub mean_active_flows: f64,
    pub peak_active_flows: f64,
}

impl CommTaxLedger {
    /// The `n` busiest links by utilization. Bounded top-N insertion:
    /// O(links × n) worst case with one n-slot buffer, instead of sorting
    /// the whole table per call. Order is deterministic: utilization
    /// descending, ties by ascending edge id (`per_link` is already in
    /// edge-id order and equal-utilization entries keep that order).
    pub fn hottest(&self, n: usize) -> Vec<&LinkUse> {
        if n == 0 {
            return Vec::new();
        }
        let mut top: Vec<&LinkUse> = Vec::with_capacity(n.min(self.per_link.len()));
        for l in &self.per_link {
            // insert after every entry at least as hot: earlier (lower-id)
            // ties stay ahead
            let pos = top.partition_point(|t| t.utilization >= l.utilization);
            if pos < n {
                if top.len() == n {
                    top.pop();
                }
                top.insert(pos, l);
            }
        }
        top
    }

    /// Payload bytes delivered for one traffic class.
    pub fn class_bytes(&self, class: TrafficClass) -> u64 {
        self.class_payload[class.index()]
    }
}

/// One member transfer of an active (possibly aggregated) flow.
struct Member {
    id: FlowId,
    bytes: u64,
    /// Stream position of the owning aggregate (`delivered` value) at which
    /// this member's last byte lands: delivered-at-join + bytes. Members
    /// are kept sorted by threshold, so the front member always completes
    /// first. Because `rate` is per member, these completion times are
    /// exactly the times the same transfers would see as separate flows.
    threshold: f64,
    submitted: SimTime,
    /// Uncontended latency over this route for this member's bytes.
    ideal: f64,
}

/// One in-flight (or staged) flow: a single transfer, or several same-route
/// transfers fused under [`AggregationPolicy::SameRoute`].
struct FlowState {
    class: TrafficClass,
    src: NodeId,
    dst: NodeId,
    /// Edge ids along the route (shares the topology's cached path storage
    /// on the HBR fast path — no per-flow copy).
    path: Arc<Vec<EdgeId>>,
    /// Wire-byte expansion per path edge (`wire_bytes / payload`); each
    /// member consumes `rate × weight` of an edge's capacity.
    weight: Vec<f64>,
    /// This flow's slot in `edge_flows[path[k]]` — the intrusive per-edge
    /// index that makes link/unlink and the dirty-component walk O(hops).
    edge_pos: Vec<u32>,
    /// Member transfers, ascending by completion threshold.
    members: VecDeque<Member>,
    /// Payload bytes streamed **per member** since activation (the
    /// aggregate's stream position; members progress in lockstep).
    delivered: f64,
    /// Current max-min fair payload rate per member (bytes/ns). The
    /// aggregate consumes `members × rate × weight` of each path edge.
    rate: f64,
    /// Fold horizon: `delivered` is exact as of this instant. Only flows
    /// whose rate changes are folded — constant-rate flows extrapolate
    /// exactly.
    updated_at: SimTime,
    /// Predicted front-member completion under the current rates.
    finish_at: SimTime,
    /// Visit stamp for the dirty-component walk (see `solve_after_change`).
    mark: u64,
}

impl FlowState {
    /// Fold the stream position forward to `now` under the current rate.
    fn fold(&mut self, now: SimTime) {
        if now > self.updated_at {
            self.delivered += self.rate * (now - self.updated_at);
            self.updated_at = now;
        }
    }
}

/// Trace record kinds (kept numeric for compact deterministic rendering).
const TRACE_SUBMIT: u8 = 0;
const TRACE_DELIVER: u8 = 1;

struct TraceRec {
    t: SimTime,
    kind: u8,
    id: FlowId,
    src: NodeId,
    dst: NodeId,
    bytes: u64,
}

type DoneCb = Box<dyn FnOnce(&mut Engine, FlowDone)>;
type AggKey = (NodeId, NodeId, TrafficClass);

/// Reusable buffers for the rate-repair pass: solves run on every flow
/// start/finish (the hot path), so the working vectors are kept across
/// calls instead of reallocated. `edges`/`flows` hold the dirty set;
/// `edge_slot` maps a touched edge id to its dense slot in the per-solve
/// vectors (`cap_left`/`wsum`/`used`).
#[derive(Default)]
struct SolveScratch {
    flows: Vec<FlowId>,
    edges: Vec<EdgeId>,
    stack: Vec<EdgeId>,
    /// Root scan order for the global pass's component enumeration
    /// (ascending active flow ids, snapshotted so the BFS can mark flows
    /// while scanning).
    roots: Vec<FlowId>,
    /// Link-disjoint component ranges as `(flow_start, flow_end,
    /// edge_start, edge_end)` into `flows`/`edges`. Contiguous by
    /// construction, so parallel workers carve disjoint slices out of the
    /// shared per-solve vectors below.
    comps: Vec<(usize, usize, usize, usize)>,
    /// Worker-partition boundaries (indices into `comps`).
    parts: Vec<usize>,
    edge_slot: Vec<usize>,
    cap_left: Vec<f64>,
    wsum: Vec<f64>,
    used: Vec<f64>,
    rate: Vec<f64>,
    frozen: Vec<bool>,
    mult: Vec<f64>,
}

/// Dirty-flow population below which a multi-component solve stays
/// sequential: thread spawn/join overhead dwarfs small fills.
const PARALLEL_SOLVE_THRESHOLD: usize = 256;

/// Default worker count for parallel residual solves. The
/// `RAYON_NUM_THREADS` convention is honored — it is the ecosystem-wide
/// knob for solver fan-out, and this engine reads it even though the
/// implementation uses scoped std threads rather than rayon (the build
/// carries no extra dependencies) — falling back to the machine's
/// available parallelism. `0` or garbage means "use the fallback".
fn default_solver_threads() -> usize {
    // detlint: allow(wall-clock) -- worker-count knob only; solved rates are byte-identical for any thread count (pinned by the parallel-vs-serial equivalence tests)
    match std::env::var("RAYON_NUM_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Progressive filling restricted to one link-disjoint component — the
/// whole solve when the dirty set is one component (the incremental fast
/// path), or one unit of a decomposed residual pass. Max-min allocations
/// decompose exactly over link-disjoint components, so filling each in
/// isolation reproduces the joint answer bit-for-bit.
///
/// `routes`/`mult`/`rate`/`frozen` are the component's flow-parallel
/// slices; `cap_left`/`wsum` its edge-parallel slices. `edge_slot` maps a
/// global edge id to its dense slot over the *whole* solve and
/// `slot_base` is this component's first slot (component slots are
/// contiguous), so workers index only their own slices. Runs on scoped
/// worker threads: everything it touches is either component-private or
/// (`edge_slot`, `links`, the atomic trip counter) shared read-only.
#[allow(clippy::too_many_arguments)]
fn fill_component(
    routes: &[(&[EdgeId], &[f64])],
    mult: &[f64],
    rate: &mut [f64],
    frozen: &mut [bool],
    cap_left: &mut [f64],
    wsum: &mut [f64],
    edge_slot: &[usize],
    slot_base: usize,
    links: &[LinkSpec],
    guard_trips: &AtomicU64,
) {
    let nf = routes.len();
    let mut left = nf;
    while left > 0 {
        for w in wsum.iter_mut() {
            *w = 0.0;
        }
        for (i, (path, weight)) in routes.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            for (k, &e) in path.iter().enumerate() {
                wsum[edge_slot[e] - slot_base] += mult[i] * weight[k];
            }
        }
        let mut inc = f64::INFINITY;
        for (j, &w) in wsum.iter().enumerate() {
            if w > 0.0 {
                let room = (cap_left[j] / w).max(0.0);
                if room < inc {
                    inc = room;
                }
            }
        }
        if !inc.is_finite() {
            break;
        }
        for (i, r) in rate.iter_mut().enumerate() {
            if !frozen[i] {
                *r += inc;
            }
        }
        for (j, w) in wsum.iter().enumerate() {
            if *w > 0.0 {
                cap_left[j] -= inc * *w;
            }
        }
        let mut any = false;
        for (i, (path, _)) in routes.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            if path.iter().any(|&e| cap_left[edge_slot[e] - slot_base] <= links[e].bw * 1e-9) {
                frozen[i] = true;
                left -= 1;
                any = true;
            }
        }
        if !any {
            // Numerical guard: finite headroom remains but no link in this
            // component crossed its saturation tolerance this round. The
            // partial allocation stands; every first round assigns a
            // positive increment, so no flow can be silently stranded at
            // rate 0 — asserted below so a regression fails loudly in
            // debug builds instead of stalling a simulation. Trips are
            // counted in an always-compiled atomic stat
            // ([`FabricSim::rate_guard_trips`]) whose fetch-and-add doubles
            // as the once-only log latch: exactly one worker observes the
            // 0→1 transition, so parallel component fills can neither
            // duplicate nor interleave the message.
            let prior = guard_trips.fetch_add(1, Ordering::Relaxed);
            #[cfg(debug_assertions)]
            {
                if prior == 0 {
                    eprintln!(
                        "commtax: rate-repair numerical guard tripped (component of {nf} flows, {left} unfrozen; \
                         rates stay partial; logged once, see rate_guard_trips())"
                    );
                }
                // count over the full index range, not iteration order:
                // the tally is identical however the set is traversed,
                // and the log above already printed when it fires
                let stalled = (0..nf).filter(|&i| !frozen[i] && rate[i] <= 0.0).count();
                debug_assert_eq!(stalled, 0, "rate repair left {stalled} unfrozen flow(s) at zero rate");
            }
            #[cfg(not(debug_assertions))]
            let _ = prior;
            break;
        }
    }
}

/// Interior state of the simulator (single-threaded, event-callback shared).
struct FlowNet {
    topo: Topology,
    /// Link spec per directed edge (parallel to the topology edge list).
    links: Vec<LinkSpec>,
    policy: RoutingPolicy,
    solver: RateSolver,
    aggregation: AggregationPolicy,
    batching: AdmissionBatching,
    /// Worker threads a residual/global solve may fan out over (1 =
    /// always sequential; results are byte-identical either way).
    solver_threads: usize,
    /// Dirty-flow population below which multi-component solves stay
    /// sequential.
    par_threshold: usize,
    /// Union of seed edges of flow starts deferred at the current instant
    /// (under [`AdmissionBatching::Coalesce`]); consumed by the
    /// same-instant flush event or drained into a same-instant
    /// completion batch's solve, whichever runs first.
    pending_seeds: Vec<EdgeId>,
    /// Instant the pending batch belongs to (debug cross-check: the flush
    /// must run before sim time advances past it).
    pending_at: SimTime,
    /// Batch generation: a queued flush acts only if no other solve
    /// consumed its batch first.
    pending_gen: u64,
    /// Introspection: flow starts whose rate solve was deferred into a
    /// batch, and deferred batches flushed by their own event.
    deferred_starts: u64,
    admission_flushes: u64,
    /// Flows streaming right now (BTreeMap: deterministic iteration order).
    active: BTreeMap<FlowId, FlowState>,
    /// Flows submitted but still paying the head-of-message hop latency.
    staged: BTreeMap<FlowId, FlowState>,
    // detlint: allow(hash-order) -- keyed insert/remove by FlowId only; callbacks fire in event-heap order, the map is never iterated
    pending_cb: HashMap<FlowId, DoneCb>,
    next_id: FlowId,
    /// Generation counter: bumped on every rate repair so completion
    /// events scheduled under an older rate assignment become no-ops.
    epoch: u64,
    /// Clock of the last state advance.
    last_t: SimTime,
    /// Active flows crossing each edge, as `(flow id, index of this edge
    /// in that flow's path)` — the interference-graph adjacency the
    /// incremental solver walks, maintained intrusively via
    /// `FlowState::edge_pos`.
    edge_flows: Vec<Vec<(FlowId, u32)>>,
    /// Current total wire rate per edge (bytes/ns), for lazy utilization
    /// integration: `edge_util_ns[e]` is exact as of `edge_seen[e]`.
    edge_rate: Vec<f64>,
    edge_seen: Vec<f64>,
    /// Completion-time index over active flows.
    heap: FinishHeap,
    /// Member transfers currently streaming (= active flow count when
    /// aggregation is off).
    active_members: u64,
    /// Open aggregates by route key (only populated under
    /// [`AggregationPolicy::SameRoute`]; entries always refer to active
    /// flows and the newest same-key leader wins).
    // detlint: allow(hash-order) -- keyed get/insert/remove by AggKey only; aggregate membership decisions never iterate this map
    agg_index: HashMap<AggKey, FlowId>,
    /// Members that joined an existing aggregate (introspection).
    joined: u64,
    /// Visit stamps for the dirty-component walk (no clearing pass).
    mark: u64,
    edge_mark: Vec<u64>,
    /// Live flow count per edge (routing signal + peak tracking; counts
    /// members, not aggregates, so PBR decisions and `peak_flows` are
    /// identical with aggregation on or off).
    flows_on_edge: Vec<u32>,
    // ----- ledger -------------------------------------------------------
    edge_payload: Vec<u64>,
    edge_util_ns: Vec<f64>,
    edge_peak: Vec<u32>,
    class_payload: [u64; TrafficClass::COUNT],
    total_payload: u64,
    completed: u64,
    contention: Summary,
    concurrency: TimeWeighted,
    /// Rate-repair rounds the numerical guard cut short (finite headroom
    /// left but no link crossed its saturation tolerance). Always
    /// compiled, so release builds surface partial rate allocations
    /// instead of silently accepting them. Atomic because parallel
    /// component fills bump it from worker threads, and its 0→1
    /// transition latches the once-only debug log.
    rate_guard_trips: AtomicU64,
    trace: Vec<TraceRec>,
    trace_cap: usize,
    scratch: SolveScratch,
    /// Hook ids registered with the engine currently driving this fabric —
    /// the allocation-free lane for the three hot event shapes (flow
    /// activation, completion timer, admission flush). Re-registered
    /// lazily whenever a different engine shows up (`Engine::id`).
    hooks: Option<FlowHooks>,
}

/// Per-engine handles into [`Engine::register_hook`] — `Copy`, so the hot
/// path reads them out of the borrow before scheduling.
#[derive(Clone, Copy)]
struct FlowHooks {
    engine: u64,
    activate: HookId,
    complete: HookId,
    flush: HookId,
}

impl FlowNet {
    fn new(topo: Topology, policy: RoutingPolicy, links: Vec<LinkSpec>) -> Self {
        let ne = links.len();
        FlowNet {
            topo,
            links,
            policy,
            solver: RateSolver::default(),
            aggregation: AggregationPolicy::default(),
            batching: AdmissionBatching::default(),
            solver_threads: default_solver_threads(),
            par_threshold: PARALLEL_SOLVE_THRESHOLD,
            pending_seeds: Vec::new(),
            pending_at: 0.0,
            pending_gen: 0,
            deferred_starts: 0,
            admission_flushes: 0,
            active: BTreeMap::new(),
            staged: BTreeMap::new(),
            // detlint: allow(hash-order) -- ctor of the keyed-lookup-only map waived at its declaration
            pending_cb: HashMap::new(),
            next_id: 0,
            epoch: 0,
            last_t: 0.0,
            edge_flows: vec![Vec::new(); ne],
            edge_rate: vec![0.0; ne],
            edge_seen: vec![0.0; ne],
            heap: FinishHeap::new(),
            active_members: 0,
            // detlint: allow(hash-order) -- ctor of the keyed-lookup-only map waived at its declaration
            agg_index: HashMap::new(),
            joined: 0,
            mark: 0,
            edge_mark: vec![0; ne],
            flows_on_edge: vec![0; ne],
            edge_payload: vec![0; ne],
            edge_util_ns: vec![0.0; ne],
            edge_peak: vec![0; ne],
            class_payload: [0; TrafficClass::COUNT],
            total_payload: 0,
            completed: 0,
            contention: Summary::new(),
            concurrency: TimeWeighted::new(),
            rate_guard_trips: AtomicU64::new(0),
            trace: Vec::new(),
            trace_cap: 1 << 16,
            scratch: SolveScratch::default(),
            hooks: None,
        }
    }

    /// Pick a route for (src, dst). HBR: the cached shortest path. PBR:
    /// the equal-cost candidate whose most-loaded edge carries the fewest
    /// live flows (deterministic tie-break on candidate order).
    fn route(&self, src: NodeId, dst: NodeId) -> Option<Arc<Vec<EdgeId>>> {
        match self.policy {
            // HBR: share the cache's Arc directly — no copy per flow.
            RoutingPolicy::Hbr => self.topo.shortest_path(src, dst),
            RoutingPolicy::Pbr => {
                let cands = self.topo.equal_cost_paths_cached(src, dst, 8);
                if cands.is_empty() {
                    return None;
                }
                let mut best = 0usize;
                let mut best_key = (u32::MAX, u64::MAX);
                for (i, p) in cands.iter().enumerate() {
                    let peak = p.iter().map(|&e| self.flows_on_edge[e]).max().unwrap_or(0);
                    let sum: u64 = p.iter().map(|&e| self.flows_on_edge[e] as u64).sum();
                    if (peak, sum) < best_key {
                        best_key = (peak, sum);
                        best = i;
                    }
                }
                Some(Arc::new(cands[best].clone()))
            }
        }
    }

    /// Fixed hop latency and bottleneck wire time of a concrete route —
    /// the idle (analytic-equivalent) cost of moving `bytes` over it.
    /// [`FabricSim::estimate`] and flow submission share this, so
    /// `FlowDone::ideal` can never drift from the public estimate.
    fn hop_wire(&self, path: &[EdgeId], bytes: u64) -> (f64, f64) {
        let mut hop = 0.0;
        let mut wire: f64 = 0.0;
        for &e in path {
            hop += self.links[e].hop_latency();
            wire = wire.max(self.links[e].wire_time(bytes));
        }
        (hop, wire)
    }

    /// Move the net clock to `now`. Flow progress and edge utilization are
    /// folded lazily (per flow on rate change, per edge on rate change or
    /// ledger snapshot), so this is O(1). The clock never moves backwards
    /// (a fresh engine driving an old sim resumes from the high-water mark).
    fn advance(&mut self, now: SimTime) {
        if now > self.last_t {
            self.last_t = now;
        }
    }

    /// Utilization-seconds of edge `e` integrated up to `t` (the stored
    /// integral plus the tail under the current rate). Read-only: ledger
    /// snapshots must not perturb solver state.
    fn edge_util_to(&self, e: EdgeId, t: SimTime) -> f64 {
        let mut u = self.edge_util_ns[e];
        let dt = t - self.edge_seen[e];
        if dt > 0.0 && self.edge_rate[e] > 0.0 {
            u += dt * (self.edge_rate[e] / self.links[e].bw).min(1.0);
        }
        u
    }

    /// Activate a staged flow at `now`: join an open same-route aggregate
    /// (under [`AggregationPolicy::SameRoute`]) or enter the active set as
    /// its own flow. Returns the seed edges the rate repair must start
    /// from — the caller either solves immediately or defers the seeds
    /// into the current instant's admission batch.
    fn start_flow(&mut self, now: SimTime, id: FlowId, mut f: FlowState) -> Arc<Vec<EdgeId>> {
        debug_assert_eq!(f.members.len(), 1, "staged flows carry exactly one member");
        let key: AggKey = (f.src, f.dst, f.class);
        let mut lead = None;
        if self.aggregation == AggregationPolicy::SameRoute {
            if let Some(&cand) = self.agg_index.get(&key) {
                if let Some(agg) = self.active.get(&cand) {
                    // the staged flow routed independently (PBR may have
                    // spread it); fuse only on the identical edge path
                    if Arc::ptr_eq(&agg.path, &f.path) || agg.path == f.path {
                        lead = Some(cand);
                    }
                }
            }
        }
        self.active_members += 1;
        self.concurrency.set(now, self.active_members as f64);
        let seeds: Arc<Vec<EdgeId>> = match lead {
            Some(cand) => {
                let mut m = f.members.pop_front().expect("staged member");
                let agg = self.active.get_mut(&cand).expect("aggregate is active");
                // anchor the member's completion threshold on the bytes the
                // aggregate has delivered per member up to this instant
                agg.fold(now);
                m.threshold = agg.delivered + m.bytes as f64;
                let pos = agg.members.partition_point(|x| x.threshold <= m.threshold);
                agg.members.insert(pos, m);
                self.joined += 1;
                agg.path.clone()
            }
            None => {
                f.updated_at = now;
                f.members[0].threshold = f.members[0].bytes as f64;
                debug_assert!(f.edge_pos.is_empty());
                for (k, &e) in f.path.iter().enumerate() {
                    f.edge_pos.push(self.edge_flows[e].len() as u32);
                    self.edge_flows[e].push((id, k as u32));
                }
                let seeds = f.path.clone();
                self.active.insert(id, f);
                if self.aggregation == AggregationPolicy::SameRoute {
                    self.agg_index.insert(key, id);
                }
                seeds
            }
        };
        seeds
    }

    /// Remove a completed flow from the per-edge index, fixing the
    /// back-pointer of each entry displaced by the swap-remove. `f` must
    /// already be out of `active`.
    fn unlink(&mut self, id: FlowId, f: &FlowState) {
        for (k, &e) in f.path.iter().enumerate() {
            let pos = f.edge_pos[k] as usize;
            let list = &mut self.edge_flows[e];
            debug_assert_eq!(list[pos].0, id, "edge index back-pointer");
            list.swap_remove(pos);
            if pos < list.len() {
                let (moved_id, moved_k) = list[pos];
                let mf = self.active.get_mut(&moved_id).expect("moved entry is active");
                mf.edge_pos[moved_k as usize] = pos as u32;
            }
        }
    }

    /// Repair max-min rates after a change touching `seeds` edges.
    ///
    /// Incremental mode walks the interference graph (flows ↔ shared
    /// edges) from the seeds to collect the dirty component; every edge a
    /// dirty flow crosses is in the dirty edge set, so all competitors for
    /// those edges are dirty too and the restricted progressive filling is
    /// exactly the global solution on that component. Flows outside keep
    /// their rates, fold horizons, and heap entries untouched.
    ///
    /// When the component outgrows
    /// [`RateSolver::Incremental::global_fraction`] — or under
    /// [`RateSolver::Global`] — the residual pass enumerates *every*
    /// link-disjoint component of the active population (same stamped
    /// BFS, roots scanned in ascending flow id, so the enumeration is
    /// canonical) and fills each independently, fanning components out
    /// over scoped worker threads when the population is large enough.
    /// Seed edges no surviving flow crosses stay in the set either way,
    /// so rates of just-removed flows integrate to zero.
    fn solve_after_change(&mut self, now: SimTime, seeds: &[EdgeId]) {
        self.epoch += 1;
        let mut s = std::mem::take(&mut self.scratch);
        s.flows.clear();
        s.edges.clear();
        s.stack.clear();
        s.comps.clear();
        let mut global = matches!(self.solver, RateSolver::Global);
        if !global {
            self.mark += 1;
            let stamp = self.mark;
            for &e in seeds {
                if self.edge_mark[e] != stamp {
                    self.edge_mark[e] = stamp;
                    s.stack.push(e);
                }
            }
            while let Some(e) = s.stack.pop() {
                s.edges.push(e);
                for &(fid, _) in &self.edge_flows[e] {
                    let f = self.active.get_mut(&fid).expect("indexed flow is active");
                    if f.mark == stamp {
                        continue;
                    }
                    f.mark = stamp;
                    s.flows.push(fid);
                    for &e2 in f.path.iter() {
                        if self.edge_mark[e2] != stamp {
                            self.edge_mark[e2] = stamp;
                            s.stack.push(e2);
                        }
                    }
                }
            }
            if let RateSolver::Incremental { global_fraction } = self.solver {
                if (s.flows.len() as f64) > global_fraction * (self.active.len() as f64) {
                    global = true;
                }
            }
            if !global {
                // one dirty component spanning the whole set
                s.comps.push((0, s.flows.len(), 0, s.edges.len()));
            }
        }
        if global {
            // Residual global pass: enumerate every link-disjoint
            // component of the active population with the same stamped
            // BFS, scanning roots in ascending flow id (each component is
            // discovered at its minimum member id). The enumeration — and
            // with it each component's flow/edge order and all filling
            // arithmetic — is canonical: independent of the seeds and of
            // how many workers later solve it.
            self.mark += 1;
            let stamp = self.mark;
            s.flows.clear();
            s.edges.clear();
            s.roots.clear();
            s.roots.extend(self.active.keys().copied());
            for &root in &s.roots {
                let (f0, e0) = (s.flows.len(), s.edges.len());
                {
                    let f = self.active.get_mut(&root).expect("rooted flow is active");
                    if f.mark == stamp {
                        continue;
                    }
                    f.mark = stamp;
                    s.flows.push(root);
                    for &e in f.path.iter() {
                        if self.edge_mark[e] != stamp {
                            self.edge_mark[e] = stamp;
                            s.stack.push(e);
                        }
                    }
                }
                while let Some(e) = s.stack.pop() {
                    s.edges.push(e);
                    for &(fid, _) in &self.edge_flows[e] {
                        let f = self.active.get_mut(&fid).expect("indexed flow is active");
                        if f.mark == stamp {
                            continue;
                        }
                        f.mark = stamp;
                        s.flows.push(fid);
                        for &e2 in f.path.iter() {
                            if self.edge_mark[e2] != stamp {
                                self.edge_mark[e2] = stamp;
                                s.stack.push(e2);
                            }
                        }
                    }
                }
                s.comps.push((f0, s.flows.len(), e0, s.edges.len()));
            }
            // Seed edges no surviving flow crosses (routes of just-removed
            // flows) form a trailing flowless range: the write-back below
            // integrates them under their previous rate and zeroes them,
            // exactly as the old single-pass global solve did.
            let e0 = s.edges.len();
            for &e in seeds {
                if self.edge_mark[e] != stamp {
                    self.edge_mark[e] = stamp;
                    s.edges.push(e);
                }
            }
            if s.edges.len() > e0 {
                s.comps.push((s.flows.len(), s.flows.len(), e0, s.edges.len()));
            }
        }

        // ---- progressive filling over the dirty components --------------
        if s.edge_slot.len() < self.links.len() {
            s.edge_slot.resize(self.links.len(), 0);
        }
        for (j, &e) in s.edges.iter().enumerate() {
            s.edge_slot[e] = j;
        }
        let nf = s.flows.len();
        s.cap_left.clear();
        s.cap_left.extend(s.edges.iter().map(|&e| self.links[e].bw));
        s.wsum.clear();
        s.wsum.resize(s.edges.len(), 0.0);
        s.used.clear();
        s.used.resize(s.edges.len(), 0.0);
        s.rate.clear();
        s.rate.resize(nf, 0.0);
        s.frozen.clear();
        s.frozen.resize(nf, false);
        s.mult.clear();
        s.mult.extend(s.flows.iter().map(|id| self.active[id].members.len() as f64));
        {
            // Per-flow route views: one BTreeMap lookup per solve instead
            // of one per filling round, and a plain-data (`Sync`) view the
            // scoped workers can share.
            let active = &self.active;
            let routes: Vec<(&[EdgeId], &[f64])> = s
                .flows
                .iter()
                .map(|id| {
                    let f = &active[id];
                    (f.path.as_slice(), f.weight.as_slice())
                })
                .collect();
            let links: &[LinkSpec] = &self.links;
            let guard = &self.rate_guard_trips;
            let threads = self.solver_threads.min(s.comps.len()).max(1);
            if threads > 1 && nf >= self.par_threshold {
                // One scoped worker per contiguous component group,
                // balanced by flow count. Component flow/edge ranges are
                // contiguous by construction, so each group carves
                // disjoint `&mut` ranges out of the shared scratch; the
                // per-component arithmetic is identical wherever it runs,
                // which is what makes results byte-equal for every thread
                // count (including 1).
                s.parts.clear();
                s.parts.push(0);
                let per = nf.div_ceil(threads);
                let mut acc = 0usize;
                for (ci, c) in s.comps.iter().enumerate() {
                    if acc >= per && s.parts.len() < threads {
                        s.parts.push(ci);
                        acc = 0;
                    }
                    acc += c.1 - c.0;
                }
                s.parts.push(s.comps.len());
                let edge_slot: &[usize] = &s.edge_slot;
                let parts: &[usize] = &s.parts;
                let comps_all: &[(usize, usize, usize, usize)] = &s.comps;
                let mut rate_rest = s.rate.as_mut_slice();
                let mut frozen_rest = s.frozen.as_mut_slice();
                let mut cap_rest = s.cap_left.as_mut_slice();
                let mut wsum_rest = s.wsum.as_mut_slice();
                let mut routes_rest = routes.as_slice();
                let mut mult_rest = s.mult.as_slice();
                std::thread::scope(|sc| {
                    for w in parts.windows(2) {
                        let comps = &comps_all[w[0]..w[1]];
                        if comps.is_empty() {
                            continue;
                        }
                        let (first, last) = (comps[0], comps[comps.len() - 1]);
                        let (nfl, nel) = (last.1 - first.0, last.3 - first.2);
                        let (base_f, base_e) = (first.0, first.2);
                        let (rate_g, rest) = rate_rest.split_at_mut(nfl);
                        rate_rest = rest;
                        let (frozen_g, rest) = frozen_rest.split_at_mut(nfl);
                        frozen_rest = rest;
                        let (cap_g, rest) = cap_rest.split_at_mut(nel);
                        cap_rest = rest;
                        let (wsum_g, rest) = wsum_rest.split_at_mut(nel);
                        wsum_rest = rest;
                        let (routes_g, rest) = routes_rest.split_at(nfl);
                        routes_rest = rest;
                        let (mult_g, rest) = mult_rest.split_at(nfl);
                        mult_rest = rest;
                        sc.spawn(move || {
                            for &(f0, f1, e0, e1) in comps {
                                let (lf0, lf1) = (f0 - base_f, f1 - base_f);
                                let (le0, le1) = (e0 - base_e, e1 - base_e);
                                fill_component(
                                    &routes_g[lf0..lf1],
                                    &mult_g[lf0..lf1],
                                    &mut rate_g[lf0..lf1],
                                    &mut frozen_g[lf0..lf1],
                                    &mut cap_g[le0..le1],
                                    &mut wsum_g[le0..le1],
                                    edge_slot,
                                    e0,
                                    links,
                                    guard,
                                );
                            }
                        });
                    }
                });
            } else {
                for &(f0, f1, e0, e1) in &s.comps {
                    fill_component(
                        &routes[f0..f1],
                        &s.mult[f0..f1],
                        &mut s.rate[f0..f1],
                        &mut s.frozen[f0..f1],
                        &mut s.cap_left[e0..e1],
                        &mut s.wsum[e0..e1],
                        &s.edge_slot,
                        e0,
                        links,
                        guard,
                    );
                }
            }
        }

        // ---- write back: fold at the old rate, then swap in the new -----
        for (i, id) in s.flows.iter().enumerate() {
            let f = self.active.get_mut(id).expect("solved flow is active");
            f.fold(now);
            f.rate = s.rate[i];
            let front = f.members.front().expect("active flow has members");
            f.finish_at =
                if f.rate > 0.0 { now + (front.threshold - f.delivered).max(0.0) / f.rate } else { f64::INFINITY };
            self.heap.upsert(*id, f.finish_at);
            for (k, &e) in f.path.iter().enumerate() {
                s.used[s.edge_slot[e]] += s.rate[i] * s.mult[i] * f.weight[k];
            }
        }
        for (j, &e) in s.edges.iter().enumerate() {
            // integrate the edge under its previous rate before switching
            let dt = now - self.edge_seen[e];
            if dt > 0.0 && self.edge_rate[e] > 0.0 {
                self.edge_util_ns[e] += dt * (self.edge_rate[e] / self.links[e].bw).min(1.0);
            }
            self.edge_seen[e] = now;
            self.edge_rate[e] = s.used[j];
        }
        self.scratch = s;
    }

    fn record_trace(&mut self, t: SimTime, kind: u8, id: FlowId, src: NodeId, dst: NodeId, bytes: u64) {
        if self.trace.len() < self.trace_cap {
            self.trace.push(TraceRec { t, kind, id, src, dst, bytes });
        }
    }

    /// Ledger bookkeeping for one member delivery.
    fn settle_member(
        &mut self,
        m: &Member,
        class: TrafficClass,
        src: NodeId,
        dst: NodeId,
        path: &[EdgeId],
        now: SimTime,
    ) -> FlowDone {
        for &e in path {
            self.edge_payload[e] += m.bytes;
            self.flows_on_edge[e] = self.flows_on_edge[e].saturating_sub(1);
        }
        self.total_payload += m.bytes;
        self.class_payload[class.index()] += m.bytes;
        self.completed += 1;
        let latency = now - m.submitted;
        let contention = (latency - m.ideal).max(0.0);
        self.contention.add(contention);
        self.record_trace(now, TRACE_DELIVER, m.id, src, dst, m.bytes);
        FlowDone {
            id: m.id,
            class,
            src,
            dst,
            bytes: m.bytes,
            submitted: m.submitted,
            arrival: now,
            latency,
            ideal: m.ideal,
            contention,
            hops: path.len(),
        }
    }
}

/// Flow-level contention-aware fabric simulator. Cheap to clone: clones
/// share the same interior state (the handle is an `Rc`), which is what
/// event callbacks capture.
#[derive(Clone)]
pub struct FabricSim {
    net: Rc<RefCell<FlowNet>>,
}

impl std::fmt::Debug for FabricSim {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.net.try_borrow() {
            Ok(n) => f
                .debug_struct("FabricSim")
                .field("active", &n.active.len())
                .field("completed", &n.completed)
                .field("edges", &n.links.len())
                .finish(),
            Err(_) => f.debug_struct("FabricSim").finish_non_exhaustive(),
        }
    }
}

/// Lifting an analytic [`super::Fabric`] into the flow-level simulator
/// moves its topology and per-edge link-spec table wholesale — the table
/// is built exactly once, whichever substrate prices the traffic first.
/// Heterogeneous assemblies ([`crate::datacenter::cluster::Supercluster`])
/// construct one `Fabric` and lift it, instead of re-running their
/// per-edge spec closure against a second constructor.
impl From<super::Fabric> for FabricSim {
    fn from(fabric: super::Fabric) -> Self {
        let super::Fabric { topo, links, policy, .. } = fabric;
        FabricSim { net: Rc::new(RefCell::new(FlowNet::new(topo, policy, links))) }
    }
}

impl FabricSim {
    /// Homogeneous fabric: every edge of `topo` uses `link`.
    pub fn new(topo: Topology, link: LinkSpec, policy: RoutingPolicy) -> Self {
        Self::new_with(topo, policy, |_, _| link.clone())
    }

    /// Heterogeneous fabric: per-edge link specs chosen by `link_for`.
    /// Delegates to the analytic constructor and lifts the result, so the
    /// two substrates share one spec-table builder.
    pub fn new_with(topo: Topology, policy: RoutingPolicy, link_for: impl Fn(EdgeId, &Topology) -> LinkSpec) -> Self {
        super::Fabric::new_with(topo, policy, link_for).into()
    }

    /// Endpoint node ids of the owned topology.
    pub fn endpoints(&self) -> Vec<NodeId> {
        self.net.borrow().topo.endpoints().to_vec()
    }

    /// Run `f` against the owned topology.
    pub fn with_topology<R>(&self, f: impl FnOnce(&Topology) -> R) -> R {
        f(&self.net.borrow().topo)
    }

    /// Routing policy in force.
    pub fn policy(&self) -> RoutingPolicy {
        self.net.borrow().policy
    }

    /// Rate-repair strategy in force.
    pub fn rate_solver(&self) -> RateSolver {
        self.net.borrow().solver
    }

    /// Set the rate-repair strategy. Incremental repair (the default) is
    /// exactly equivalent to the global pass — this knob exists for A/B
    /// measurement and as an escape hatch.
    pub fn set_rate_solver(&self, solver: RateSolver) {
        self.net.borrow_mut().solver = solver;
    }

    /// Aggregation policy in force.
    pub fn aggregation(&self) -> AggregationPolicy {
        self.net.borrow().aggregation
    }

    /// Set the aggregation policy. Takes effect for flows activated from
    /// now on (in-flight flows keep their shape); set it before traffic
    /// for a uniform run.
    pub fn set_aggregation(&self, policy: AggregationPolicy) {
        self.net.borrow_mut().aggregation = policy;
    }

    /// Admission batching policy in force.
    pub fn admission_batching(&self) -> AdmissionBatching {
        self.net.borrow().batching
    }

    /// Set the admission batching policy. Coalesce (the default) is
    /// exactly equivalent to Immediate — zero sim time elapses between a
    /// batch's starts and its flush — so this knob exists for A/B
    /// measurement. Set it before traffic for a uniform run.
    pub fn set_admission_batching(&self, batching: AdmissionBatching) {
        self.net.borrow_mut().batching = batching;
    }

    /// Worker threads a residual/global rate solve may fan out over.
    pub fn solver_threads(&self) -> usize {
        self.net.borrow().solver_threads
    }

    /// Set the solver worker count (clamped to ≥ 1; 1 means always
    /// sequential). The default honors `RAYON_NUM_THREADS`, else the
    /// machine's available parallelism. Results are byte-identical for
    /// every value — the knob only moves wall-clock time.
    pub fn set_solver_threads(&self, threads: usize) {
        self.net.borrow_mut().solver_threads = threads.max(1);
    }

    /// Dirty-flow population at which residual solves start fanning
    /// components out over worker threads.
    pub fn parallel_solve_threshold(&self) -> usize {
        self.net.borrow().par_threshold
    }

    /// Set the parallel-solve threshold (tests pin it to 1 to force the
    /// decomposed path on tiny workloads; the default keeps small solves
    /// sequential, where thread spawn overhead would dominate).
    pub fn set_parallel_solve_threshold(&self, flows: usize) {
        self.net.borrow_mut().par_threshold = flows;
    }

    /// Flow starts whose rate solve was deferred into a same-instant
    /// admission batch so far (0 under [`AdmissionBatching::Immediate`]).
    pub fn deferred_starts(&self) -> u64 {
        self.net.borrow().deferred_starts
    }

    /// Deferred admission batches flushed by their own same-instant event
    /// so far. Strictly fewer than [`Self::deferred_starts`] on workloads
    /// with same-timestamp waves — each gap is a rate solve amortized away
    /// (batches drained by a same-instant completion batch don't count;
    /// those cost zero extra solves).
    pub fn admission_flushes(&self) -> u64 {
        self.net.borrow().admission_flushes
    }

    /// Link spec of a directed edge (cloned out of the shared state).
    pub fn link(&self, e: EdgeId) -> LinkSpec {
        self.net.borrow().links[e].clone()
    }

    /// The route the current policy would pick right now (edge ids), or
    /// `None` when unreachable. Same selection logic as [`Self::submit`].
    /// Shares the cached path storage (`Arc`) — no per-call copy; clone
    /// the inner `Vec` only if you need to own or mutate it.
    pub fn route(&self, src: NodeId, dst: NodeId) -> Option<Arc<Vec<EdgeId>>> {
        if src == dst {
            return Some(Arc::new(Vec::new()));
        }
        self.net.borrow().route(src, dst)
    }

    /// Whether the current policy can route `src` → `dst`, without copying
    /// a path out (the cheap pre-check for callers that must not lose
    /// their completion callback to an unroutable [`Self::submit_with`]).
    pub fn reachable(&self, src: NodeId, dst: NodeId) -> bool {
        src == dst || self.net.borrow().route(src, dst).is_some()
    }

    /// Transfers currently streaming (members of active flows; excludes
    /// staged submissions). Counts members, not aggregates, so the figure
    /// is independent of [`AggregationPolicy`].
    pub fn active_flows(&self) -> usize {
        self.net.borrow().active_members as usize
    }

    /// Flow objects the rate solver currently handles (= active transfers
    /// when aggregation is off; the compressed population when on).
    pub fn active_aggregates(&self) -> usize {
        self.net.borrow().active.len()
    }

    /// Members that joined an existing aggregate so far (0 unless
    /// [`AggregationPolicy::SameRoute`] is on and same-route concurrency
    /// actually occurred).
    pub fn aggregated_joins(&self) -> u64 {
        self.net.borrow().joined
    }

    /// Flows delivered so far.
    pub fn completed(&self) -> u64 {
        self.net.borrow().completed
    }

    /// Rate-repair rounds the numerical guard cut short so far (finite
    /// headroom left but no link crossed its saturation tolerance; the
    /// partial rate allocation stood). Always compiled — 0 on healthy
    /// runs; a nonzero count in release builds is the signal the old
    /// debug-only `eprintln!` could never deliver.
    pub fn rate_guard_trips(&self) -> u64 {
        self.net.borrow().rate_guard_trips.load(Ordering::Relaxed)
    }

    /// Payload bytes delivered so far.
    pub fn total_payload(&self) -> u64 {
        self.net.borrow().total_payload
    }

    /// Payload bytes delivered across one directed edge so far.
    pub fn edge_payload(&self, e: EdgeId) -> u64 {
        self.net.borrow().edge_payload[e]
    }

    /// Time-weighted utilization of one directed edge over `[0, now]`
    /// (0 before anything has flowed). Normalizing over the caller's clock
    /// — not the last flow event — lets idle stretches decay the figure,
    /// so a dispatcher sampling it long after a burst sees a cool link.
    /// Cheaper than snapshotting the whole [`Self::ledger`] when only a
    /// handful of edges matter per decision.
    pub fn edge_utilization(&self, e: EdgeId, now: SimTime) -> f64 {
        let n = self.net.borrow();
        let span = n.last_t.max(now);
        if span <= 0.0 {
            0.0
        } else {
            (n.edge_util_to(e, n.last_t) / span).min(1.0)
        }
    }

    /// Analytic uncontended latency over the route the current policy would
    /// pick: `Σ hop_latency + max_e wire_time_e(bytes)`. The flow model
    /// reproduces exactly this figure when the fabric is otherwise idle.
    pub fn estimate(&self, src: NodeId, dst: NodeId, bytes: u64) -> Option<f64> {
        if src == dst {
            return Some(0.0);
        }
        let n = self.net.borrow();
        let path = n.route(src, dst)?;
        let (hop, wire) = n.hop_wire(&path, bytes);
        Some(hop + wire)
    }

    /// Submit a transfer at the engine's current time; `done` fires when the
    /// last byte arrives. Returns `None` (dropping `done`) when no route
    /// exists.
    pub fn submit_with(
        &self,
        eng: &mut Engine,
        tr: Transfer,
        done: impl FnOnce(&mut Engine, FlowDone) + 'static,
    ) -> Option<FlowId> {
        let now = eng.now();
        // Same-node transfers are local copies: free and instant.
        if tr.src == tr.dst {
            let id = {
                let mut n = self.net.borrow_mut();
                let id = n.next_id;
                n.next_id += 1;
                n.completed += 1;
                // keep the ledger's byte columns consistent with its flow
                // count even though no edge is crossed
                n.total_payload += tr.bytes;
                n.class_payload[tr.class.index()] += tr.bytes;
                n.contention.add(0.0);
                n.record_trace(now, TRACE_SUBMIT, id, tr.src, tr.dst, tr.bytes);
                n.record_trace(now, TRACE_DELIVER, id, tr.src, tr.dst, tr.bytes);
                id
            };
            let d = FlowDone {
                id,
                class: tr.class,
                src: tr.src,
                dst: tr.dst,
                bytes: tr.bytes,
                submitted: now,
                arrival: now,
                latency: 0.0,
                ideal: 0.0,
                contention: 0.0,
                hops: 0,
            };
            eng.schedule_in(0.0, move |e| done(e, d));
            return Some(id);
        }
        let (id, hop_lat) = {
            let mut n = self.net.borrow_mut();
            let path = n.route(tr.src, tr.dst)?;
            let (hop, wire) = n.hop_wire(&path, tr.bytes);
            let weight: Vec<f64> = path
                .iter()
                .map(|&e| {
                    let l = &n.links[e];
                    if tr.bytes > 0 { l.wire_bytes(tr.bytes) as f64 / tr.bytes as f64 } else { 1.0 }
                })
                .collect();
            let id = n.next_id;
            n.next_id += 1;
            for &e in path.iter() {
                n.flows_on_edge[e] += 1;
                if n.flows_on_edge[e] > n.edge_peak[e] {
                    n.edge_peak[e] = n.flows_on_edge[e];
                }
            }
            n.record_trace(now, TRACE_SUBMIT, id, tr.src, tr.dst, tr.bytes);
            let state = FlowState {
                class: tr.class,
                src: tr.src,
                dst: tr.dst,
                path,
                weight,
                edge_pos: Vec::new(),
                members: VecDeque::from([Member {
                    id,
                    bytes: tr.bytes,
                    threshold: tr.bytes as f64,
                    submitted: now,
                    ideal: hop + wire,
                }]),
                delivered: 0.0,
                rate: 0.0,
                updated_at: now,
                finish_at: f64::INFINITY,
                mark: 0,
            };
            n.staged.insert(id, state);
            (id, hop)
        };
        self.net.borrow_mut().pending_cb.insert(id, Box::new(done));
        // The message head pays the fixed per-hop latencies up front; the
        // body starts streaming (and competing for bandwidth) after them.
        // Hook lane: one registered handler, a bare u64 payload per event —
        // no boxed closure per submission.
        let h = Self::engine_hooks(&self.net, eng);
        eng.schedule_hook_in(hop_lat, h.activate, id);
        Some(id)
    }

    /// Submit without a completion callback.
    pub fn submit(&self, eng: &mut Engine, tr: Transfer) -> Option<FlowId> {
        self.submit_with(eng, tr, |_, _| {})
    }

    /// Submit and drive the engine until this flow delivers. Other pending
    /// flows progress naturally while waiting. Returns `None` when no route
    /// exists (or the engine drains without delivery, e.g. a horizon stop).
    pub fn transfer_sync(&self, eng: &mut Engine, tr: Transfer) -> Option<FlowDone> {
        let slot: Rc<RefCell<Option<FlowDone>>> = Rc::new(RefCell::new(None));
        let out = slot.clone();
        self.submit_with(eng, tr, move |_, d| {
            *out.borrow_mut() = Some(d);
        })?;
        // drop the read borrow before stepping: the completion callback
        // needs borrow_mut on the same cell
        loop {
            if slot.borrow().is_some() {
                break;
            }
            if !eng.step() {
                break;
            }
        }
        let d = slot.borrow_mut().take();
        d
    }

    /// Hook ids for this fabric on `eng`, registering them on first use
    /// (or when a different engine starts driving the fabric, e.g. a fresh
    /// engine per [`FabricSim::transfer_sync`] call). Registration pushes
    /// no events, so the `(time, seq)` schedule is byte-identical to the
    /// boxed-closure lane it replaces.
    fn engine_hooks(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine) -> FlowHooks {
        if let Some(h) = net.borrow().hooks {
            if h.engine == eng.id() {
                return h;
            }
        }
        let n = net.clone();
        let activate = eng.register_hook(move |e, id| Self::activate(n.clone(), e, id));
        let n = net.clone();
        let complete = eng.register_hook(move |e, epoch| {
            // a later rate change bumped the epoch ⇒ stale timer, no-op
            let live = n.borrow().epoch == epoch;
            if live {
                Self::complete_due(n.clone(), e);
            }
        });
        let n = net.clone();
        let flush = eng.register_hook(move |e, gen| Self::flush_admissions(n.clone(), e, gen));
        let h = FlowHooks { engine: eng.id(), activate, complete, flush };
        net.borrow_mut().hooks = Some(h);
        h
    }

    fn activate(net: Rc<RefCell<FlowNet>>, eng: &mut Engine, id: FlowId) {
        let now = eng.now();
        // Under Coalesce, the first deferred start of an instant schedules
        // the batch's flush; later same-instant starts just add seeds.
        let mut solved = false;
        let mut flush_gen = None;
        {
            let mut n = net.borrow_mut();
            n.advance(now);
            if let Some(f) = n.staged.remove(&id) {
                let seeds = n.start_flow(now, id, f);
                match n.batching {
                    AdmissionBatching::Immediate => {
                        n.solve_after_change(now, &seeds);
                        solved = true;
                    }
                    AdmissionBatching::Coalesce => {
                        let opens = n.pending_seeds.is_empty();
                        debug_assert!(opens || n.pending_at == now, "a pending batch never outlives its instant");
                        n.pending_seeds.extend(seeds.iter().copied());
                        n.pending_at = now;
                        n.deferred_starts += 1;
                        if opens {
                            flush_gen = Some(n.pending_gen);
                        }
                    }
                }
            }
        }
        if solved {
            Self::drive(&net, eng);
        } else if let Some(gen) = flush_gen {
            let h = Self::engine_hooks(&net, eng);
            eng.defer_hook(h.flush, gen);
        }
    }

    /// Flush a deferred admission batch: one rate repair over the union of
    /// the batch's seed edges, at the very instant the starts happened
    /// (scheduled via [`Engine::defer`], it runs after every event already
    /// queued at that instant, so the whole same-timestamp wave is in). A
    /// stale generation means a same-instant completion batch already
    /// drained these seeds into its own solve.
    fn flush_admissions(net: Rc<RefCell<FlowNet>>, eng: &mut Engine, gen: u64) {
        {
            let mut n = net.borrow_mut();
            if n.pending_gen != gen {
                return;
            }
            debug_assert!(!n.pending_seeds.is_empty(), "live flush with no pending seeds");
            let now = eng.now();
            debug_assert_eq!(n.pending_at, now, "flush must run at the admission instant");
            n.advance(now);
            n.pending_gen += 1;
            n.admission_flushes += 1;
            let mut seeds = std::mem::take(&mut n.pending_seeds);
            n.solve_after_change(now, &seeds);
            // hand the buffer back so the next batch reuses its capacity
            seeds.clear();
            n.pending_seeds = seeds;
        }
        Self::drive(&net, eng);
    }

    /// Schedule the next completion under the current rate assignment. A
    /// later rate change bumps the epoch, turning this event into a no-op.
    fn drive(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine) {
        let (next, epoch) = {
            let n = net.borrow();
            (n.heap.peek().map(|(t, _)| t).filter(|t| t.is_finite()), n.epoch)
        };
        if let Some(t) = next {
            // completion timers are the dominant event shape at scale: the
            // hook lane carries the epoch as the payload (the fire-time
            // liveness check lives in the registered handler)
            let h = Self::engine_hooks(net, eng);
            eng.schedule_hook_at(t, h.complete, epoch);
        }
    }

    fn complete_due(net: Rc<RefCell<FlowNet>>, eng: &mut Engine) {
        let now = eng.now();
        let mut done: Vec<(FlowDone, Option<DoneCb>)> = Vec::new();
        {
            let mut n = net.borrow_mut();
            n.advance(now);
            // pop everything due within the completion slack, then settle
            // in ascending flow-id order (the order the old full scan over
            // the BTreeMap produced)
            let mut due: Vec<FlowId> = Vec::new();
            while let Some((t, id)) = n.heap.peek() {
                if t <= now + 1e-6 {
                    n.heap.pop();
                    due.push(id);
                } else {
                    break;
                }
            }
            due.sort_unstable();
            let mut seeds: Vec<EdgeId> = Vec::new();
            for id in due {
                let agg = n.active.get_mut(&id).expect("due flow is active");
                agg.fold(now);
                // pop every member within the slack; near-simultaneous
                // members complete in one batch like separate flows would
                let slack = agg.rate * 1e-6;
                let mut popped: Vec<Member> = Vec::new();
                while let Some(front) = agg.members.front() {
                    if front.threshold <= agg.delivered + slack {
                        let m = agg.members.pop_front().expect("front member");
                        if m.threshold > agg.delivered {
                            agg.delivered = m.threshold; // snap float residue
                        }
                        popped.push(m);
                    } else {
                        break;
                    }
                }
                let emptied = agg.members.is_empty();
                let (class, src, dst) = (agg.class, agg.src, agg.dst);
                let path = agg.path.clone();
                for m in &popped {
                    let d = n.settle_member(m, class, src, dst, &path, now);
                    let cb = n.pending_cb.remove(&m.id);
                    done.push((d, cb));
                }
                n.active_members -= popped.len() as u64;
                if emptied {
                    let f = n.active.remove(&id).expect("emptied flow");
                    n.unlink(id, &f);
                    if n.agg_index.get(&(src, dst, class)) == Some(&id) {
                        n.agg_index.remove(&(src, dst, class));
                    }
                }
                // seed the repair from this route even when no member
                // popped (float drift between the heap key and the folded
                // stream): the re-solve reschedules the completion
                seeds.extend(path.iter().copied());
            }
            n.concurrency.set(now, n.active_members as f64);
            // Admissions deferred at this same instant fold into this
            // solve: the union of seed edges covers both the finished and
            // the just-started routes, and the batch's own flush event
            // then no-ops on the stale generation. (Starts and finishes
            // sharing a timestamp cost one solve total.)
            if !n.pending_seeds.is_empty() {
                debug_assert_eq!(n.pending_at, now, "a pending batch never outlives its instant");
                n.pending_gen += 1;
                seeds.extend(n.pending_seeds.drain(..));
            }
            n.solve_after_change(now, &seeds);
        }
        for (d, cb) in done {
            if let Some(cb) = cb {
                cb(eng, d);
            }
        }
        Self::drive(&net, eng);
    }

    /// Snapshot the communication-tax ledger.
    pub fn ledger(&self) -> CommTaxLedger {
        let n = self.net.borrow();
        let elapsed = n.last_t.max(1e-9);
        let mut per_link = Vec::new();
        let mut util_sum = 0.0;
        let mut util_peak: f64 = 0.0;
        for e in 0..n.links.len() {
            let util_ns = n.edge_util_to(e, n.last_t);
            if n.edge_payload[e] == 0 && util_ns == 0.0 {
                continue;
            }
            let (src, dst) = n.topo.edge(e);
            let utilization = (util_ns / elapsed).min(1.0);
            util_sum += utilization;
            if utilization > util_peak {
                util_peak = utilization;
            }
            per_link.push(LinkUse {
                edge: e,
                src,
                dst,
                link: n.links[e].name,
                payload: n.edge_payload[e],
                utilization,
                peak_flows: n.edge_peak[e],
            });
        }
        let mean_utilization = if per_link.is_empty() { 0.0 } else { util_sum / per_link.len() as f64 };
        CommTaxLedger {
            elapsed: n.last_t,
            flows: n.completed,
            total_payload: n.total_payload,
            class_payload: n.class_payload,
            per_link,
            contention: n.contention.clone(),
            mean_utilization,
            peak_utilization: util_peak,
            mean_active_flows: n.concurrency.mean_until(n.last_t),
            peak_active_flows: n.concurrency.peak(),
        }
    }

    /// Render the flow event trace as stable text — two runs with the same
    /// inputs produce byte-identical output (the determinism contract).
    pub fn trace_render(&self) -> String {
        let n = self.net.borrow();
        let mut out = String::new();
        for r in &n.trace {
            let kind = if r.kind == TRACE_SUBMIT { "submit" } else { "deliver" };
            out.push_str(&format!(
                "{t:.3} {kind} flow={id} {src}->{dst} bytes={bytes}\n",
                t = r.t,
                id = r.id,
                src = r.src,
                dst = r.dst,
                bytes = r.bytes,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::topology::Topology;

    fn star_sim(n: usize, policy: RoutingPolicy) -> FabricSim {
        FabricSim::new(Topology::star(n), LinkSpec::cxl3_x16(), policy)
    }

    #[test]
    fn idle_flow_matches_analytic_exactly() {
        let sim = star_sim(2, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let bytes = 1u64 << 24;
        let est = sim.estimate(eps[0], eps[1], bytes).unwrap();
        // analytic cross-check against the equivalent 2-hop CommPath
        let path = crate::datacenter::hierarchy::CommPath {
            links: vec![LinkSpec::cxl3_x16(), LinkSpec::cxl3_x16()],
            stack: crate::fabric::netstack::SoftwareStack::hw_mediated(),
        };
        assert!((est - path.time(bytes)).abs() < 1e-6, "est={est} path={}", path.time(bytes));
        let mut eng = Engine::new();
        let d = sim.transfer_sync(&mut eng, Transfer::new(eps[0], eps[1], bytes, TrafficClass::Collective)).unwrap();
        let rel = (d.latency - est).abs() / est;
        assert!(rel < 0.01, "latency={} est={est}", d.latency);
        assert!(d.contention < est * 0.01, "idle flow must pay no tax, got {}", d.contention);
    }

    #[test]
    fn rate_guard_stays_quiet_on_healthy_runs() {
        // the numerical guard is a last-resort break; ordinary contended
        // runs must converge without it, and the always-compiled counter
        // is how release builds would notice if they ever stopped doing so
        let sim = star_sim(4, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let mut eng = Engine::new();
        for i in 0..3 {
            sim.submit(&mut eng, Transfer::new(eps[i], eps[3], 1u64 << 22, TrafficClass::Collective));
        }
        eng.run();
        assert_eq!(sim.completed(), 3);
        assert_eq!(sim.rate_guard_trips(), 0);
    }

    #[test]
    fn sharing_halves_rate() {
        let sim = star_sim(3, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let bytes = 1u64 << 24;
        let solo = {
            let mut eng = Engine::new();
            sim.transfer_sync(&mut eng, Transfer::new(eps[0], eps[1], bytes, TrafficClass::Collective))
                .unwrap()
                .latency
        };
        // fresh sim: two flows leaving eps[0] at once share the e0->switch edge
        let sim = star_sim(3, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let mut eng = Engine::new();
        let done: Rc<RefCell<Vec<FlowDone>>> = Rc::new(RefCell::new(Vec::new()));
        for &dst in &[eps[1], eps[2]] {
            let d = done.clone();
            sim.submit_with(&mut eng, Transfer::new(eps[0], dst, bytes, TrafficClass::Collective), move |_, r| {
                d.borrow_mut().push(r)
            });
        }
        eng.run();
        let rs = done.borrow();
        assert_eq!(rs.len(), 2);
        for r in rs.iter() {
            assert!(r.latency > 1.8 * solo, "shared={} solo={solo}", r.latency);
            assert!(r.latency < 2.2 * solo, "shared={} solo={solo}", r.latency);
            assert!(r.contention > 0.0);
        }
    }

    #[test]
    fn maxmin_downstream_flow_gets_leftover() {
        // f1: a->b, f2: a->c (share a->sw), f3: d->b (shares sw->b with f1).
        // Max-min: f1 and f2 pinned to 1/2 by a->sw; f3 then also gets 1/2
        // of sw->b. All three finish around 2x the solo wire time.
        let sim = star_sim(4, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let bytes = 1u64 << 24;
        let solo_est = sim.estimate(eps[0], eps[1], bytes).unwrap();
        let mut eng = Engine::new();
        let done: Rc<RefCell<Vec<FlowDone>>> = Rc::new(RefCell::new(Vec::new()));
        for (s, t) in [(0usize, 1usize), (0, 2), (3, 1)] {
            let d = done.clone();
            sim.submit_with(&mut eng, Transfer::new(eps[s], eps[t], bytes, TrafficClass::Collective), move |_, r| {
                d.borrow_mut().push(r)
            });
        }
        eng.run();
        let rs = done.borrow();
        assert_eq!(rs.len(), 3);
        for r in rs.iter() {
            assert!(r.latency > 1.5 * solo_est, "latency={} solo={solo_est}", r.latency);
            assert!(r.latency < 2.5 * solo_est, "latency={} solo={solo_est}", r.latency);
        }
    }

    #[test]
    fn pbr_spreads_over_planes_hbr_contends() {
        let run = |policy| {
            let sim = FabricSim::new(Topology::single_clos(4, 2), LinkSpec::cxl3_x16(), policy);
            let eps = sim.endpoints();
            let mut eng = Engine::new();
            let worst: Rc<RefCell<f64>> = Rc::new(RefCell::new(0.0));
            for _ in 0..2 {
                let w = worst.clone();
                sim.submit_with(&mut eng, Transfer::new(eps[0], eps[1], 1 << 24, TrafficClass::Collective), move |_, r| {
                    let mut m = w.borrow_mut();
                    if r.latency > *m {
                        *m = r.latency;
                    }
                });
            }
            eng.run();
            let v = *worst.borrow();
            v
        };
        let hbr = run(RoutingPolicy::Hbr);
        let pbr = run(RoutingPolicy::Pbr);
        assert!(hbr > 1.5 * pbr, "hbr={hbr} pbr={pbr} (PBR should use the idle plane)");
    }

    #[test]
    fn ledger_conserves_bytes() {
        let sim = star_sim(4, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let mut eng = Engine::new();
        let flows = [(0usize, 1usize, 1000u64), (1, 2, 2000), (2, 3, 3000), (3, 0, 500)];
        for &(s, t, b) in &flows {
            sim.submit(&mut eng, Transfer::new(eps[s], eps[t], b, TrafficClass::KvCache));
        }
        eng.run();
        let ledger = sim.ledger();
        let demand: u64 = flows.iter().map(|f| f.2).sum();
        assert_eq!(ledger.total_payload, demand);
        // every flow crosses 2 edges in a star, so per-link sum is 2x demand
        let per_link: u64 = ledger.per_link.iter().map(|l| l.payload).sum();
        assert_eq!(per_link, 2 * demand);
        assert_eq!(ledger.flows, flows.len() as u64);
        assert_eq!(ledger.class_payload[TrafficClass::KvCache.index()], demand);
        assert!(ledger.peak_utilization > 0.0 && ledger.peak_utilization <= 1.0);
    }

    #[test]
    fn same_node_transfer_is_free() {
        let sim = star_sim(2, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let mut eng = Engine::new();
        let d = sim.transfer_sync(&mut eng, Transfer::new(eps[0], eps[0], 1 << 20, TrafficClass::Control)).unwrap();
        assert_eq!(d.latency, 0.0);
        assert_eq!(d.hops, 0);
    }

    #[test]
    fn unreachable_returns_none() {
        let mut topo = Topology::empty(crate::fabric::topology::TopologyKind::Custom);
        let a = topo.add_node(crate::fabric::topology::NodeKind::Endpoint);
        let b = topo.add_node(crate::fabric::topology::NodeKind::Endpoint);
        let sim = FabricSim::new(topo, LinkSpec::cxl3_x16(), RoutingPolicy::Hbr);
        let mut eng = Engine::new();
        assert!(sim.submit(&mut eng, Transfer::new(a, b, 64, TrafficClass::Control)).is_none());
        assert!(sim.estimate(a, b, 64).is_none());
    }

    #[test]
    fn trace_is_deterministic() {
        let run = || {
            let sim = star_sim(6, RoutingPolicy::Pbr);
            let eps = sim.endpoints();
            let mut eng = Engine::new();
            let mut rng = crate::sim::Rng::new(7);
            for _ in 0..40 {
                let a = rng.index(6);
                let b = rng.index(6);
                sim.submit(&mut eng, Transfer::new(eps[a], eps[b], 1 + rng.below(1 << 20), TrafficClass::Collective));
            }
            eng.run();
            (sim.trace_render(), sim.total_payload())
        };
        let (t1, p1) = run();
        let (t2, p2) = run();
        assert_eq!(t1, t2, "trace must be byte-identical across runs");
        assert_eq!(p1, p2);
        assert!(!t1.is_empty());
    }

    #[test]
    fn staggered_flows_reschedule_completions() {
        // A second flow arriving mid-stream slows the first one down: the
        // first flow's completion must be pushed later than its idle
        // estimate, proving completion events are rescheduled on rate change.
        let sim = star_sim(3, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let bytes = 1u64 << 26; // 64 MiB: long enough to overlap
        let est = sim.estimate(eps[0], eps[1], bytes).unwrap();
        let mut eng = Engine::new();
        let first: Rc<RefCell<Option<FlowDone>>> = Rc::new(RefCell::new(None));
        let f = first.clone();
        sim.submit_with(&mut eng, Transfer::new(eps[0], eps[1], bytes, TrafficClass::Collective), move |_, r| {
            *f.borrow_mut() = Some(r)
        });
        // inject the competitor halfway through the first flow
        let sim2 = sim.clone();
        let eps2 = eps.clone();
        eng.schedule_at(est * 0.5, move |e| {
            sim2.submit(e, Transfer::new(eps2[0], eps2[2], bytes, TrafficClass::Collective));
        });
        eng.run();
        let d = first.borrow().expect("first flow done");
        assert!(d.latency > 1.3 * est, "latency={} est={est}", d.latency);
        assert!(d.latency < 1.7 * est, "latency={} est={est}", d.latency);
    }

    #[test]
    fn solver_knobs_roundtrip_and_default_incremental() {
        let sim = star_sim(2, RoutingPolicy::Hbr);
        assert!(matches!(sim.rate_solver(), RateSolver::Incremental { .. }), "incremental repair is the default");
        assert_eq!(sim.aggregation(), AggregationPolicy::Off, "aggregation is opt-in");
        assert_eq!(sim.admission_batching(), AdmissionBatching::Coalesce, "admission batching is the default");
        sim.set_rate_solver(RateSolver::Global);
        assert_eq!(sim.rate_solver(), RateSolver::Global);
        sim.set_aggregation(AggregationPolicy::SameRoute);
        assert_eq!(sim.aggregation(), AggregationPolicy::SameRoute);
        sim.set_admission_batching(AdmissionBatching::Immediate);
        assert_eq!(sim.admission_batching(), AdmissionBatching::Immediate);
        assert!(sim.solver_threads() >= 1, "default worker count is at least one");
        sim.set_solver_threads(0);
        assert_eq!(sim.solver_threads(), 1, "thread count clamps to at least one");
        sim.set_solver_threads(4);
        assert_eq!(sim.solver_threads(), 4);
        assert_eq!(sim.parallel_solve_threshold(), 256, "small solves stay sequential by default");
        sim.set_parallel_solve_threshold(1);
        assert_eq!(sim.parallel_solve_threshold(), 1);
    }

    #[test]
    fn admission_batching_coalesces_same_instant_starts() {
        // three 2-hop submits at t=0 activate at the same instant; under
        // the default Coalesce policy they must share one rate solve
        let sim = star_sim(4, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let mut eng = Engine::new();
        for i in 0..3 {
            sim.submit(&mut eng, Transfer::new(eps[i], eps[3], 1 << 22, TrafficClass::Collective));
        }
        eng.run();
        assert_eq!(sim.completed(), 3);
        assert_eq!(sim.deferred_starts(), 3);
        assert_eq!(sim.admission_flushes(), 1, "three same-instant starts must coalesce into one flush");
        assert_eq!(sim.active_flows(), 0);
        assert_eq!(sim.rate_guard_trips(), 0);
    }

    #[test]
    fn immediate_admission_defers_nothing() {
        let sim = star_sim(4, RoutingPolicy::Hbr);
        sim.set_admission_batching(AdmissionBatching::Immediate);
        let eps = sim.endpoints();
        let mut eng = Engine::new();
        for i in 0..3 {
            sim.submit(&mut eng, Transfer::new(eps[i], eps[3], 1 << 22, TrafficClass::Collective));
        }
        eng.run();
        assert_eq!(sim.completed(), 3);
        assert_eq!(sim.deferred_starts(), 0);
        assert_eq!(sim.admission_flushes(), 0);
    }

    #[test]
    fn batched_admission_matches_immediate_admission() {
        // a same-instant fan-in wave: per-member arrivals and the ledger
        // must match the unbatched run (zero sim time elapses between a
        // batch's starts and its flush, so only the final rates matter)
        let run = |batching: AdmissionBatching| {
            let sim = star_sim(5, RoutingPolicy::Hbr);
            sim.set_admission_batching(batching);
            let eps = sim.endpoints();
            let mut eng = Engine::new();
            let done: Rc<RefCell<Vec<FlowDone>>> = Rc::new(RefCell::new(Vec::new()));
            for i in 0..4 {
                let d = done.clone();
                let bytes = (1u64 << 22) + (i as u64) * 8192; // distinct sizes
                sim.submit_with(&mut eng, Transfer::new(eps[i], eps[4], bytes, TrafficClass::KvCache), move |_, r| {
                    d.borrow_mut().push(r)
                });
            }
            eng.run();
            let mut rs: Vec<(FlowId, f64)> = done.borrow().iter().map(|r| (r.id, r.arrival)).collect();
            rs.sort_by_key(|r| r.0);
            (rs, sim.total_payload(), sim.ledger().contention.sum())
        };
        let (base, pb, cb) = run(AdmissionBatching::Immediate);
        let (got, pg, cg) = run(AdmissionBatching::Coalesce);
        assert_eq!(pb, pg);
        assert_eq!(base.len(), got.len());
        for ((ia, ta), (ib, tb)) in base.iter().zip(got.iter()) {
            assert_eq!(ia, ib);
            let rel = (ta - tb).abs() / ta.max(1.0);
            assert!(rel < 1e-9, "arrival diverged under batching: {ta} vs {tb}");
        }
        let rel = (cb - cg).abs() / cb.abs().max(1.0);
        assert!(rel < 1e-9, "contention diverged under batching: {cb} vs {cg}");
    }

    #[test]
    fn parallel_residual_solve_is_bit_identical() {
        // disjoint pairs on a star fabric give every global pass several
        // link-disjoint components; forcing the parallel path (threshold
        // 1) must not move a single bit relative to one worker
        let run = |threads: usize| {
            let sim = star_sim(8, RoutingPolicy::Hbr);
            sim.set_rate_solver(RateSolver::Global);
            sim.set_solver_threads(threads);
            sim.set_parallel_solve_threshold(1);
            let eps = sim.endpoints();
            let mut eng = Engine::new();
            let done: Rc<RefCell<Vec<FlowDone>>> = Rc::new(RefCell::new(Vec::new()));
            let pairs = [(0usize, 1usize), (2, 3), (4, 5), (6, 7), (0, 2), (4, 6), (1, 3), (5, 7)];
            for (i, &(a, b)) in pairs.iter().enumerate() {
                let d = done.clone();
                let bytes = (1u64 << 22) + (i as u64) * 4096;
                sim.submit_with(&mut eng, Transfer::new(eps[a], eps[b], bytes, TrafficClass::Collective), move |_, r| {
                    d.borrow_mut().push(r)
                });
            }
            eng.run();
            let mut rs: Vec<(FlowId, u64)> = done.borrow().iter().map(|r| (r.id, r.arrival.to_bits())).collect();
            rs.sort_by_key(|r| r.0);
            (rs, sim.trace_render(), sim.total_payload())
        };
        let (base, trace1, pay1) = run(1);
        assert_eq!(base.len(), 8);
        for threads in [2, 8] {
            let (got, trace_n, pay_n) = run(threads);
            assert_eq!(base, got, "{threads} workers changed an arrival bit");
            assert_eq!(trace1, trace_n, "{threads} workers changed the trace");
            assert_eq!(pay1, pay_n);
        }
    }

    #[test]
    fn incremental_repair_leaves_disjoint_flows_untouched() {
        // line(4): 0-1 and 2-3 share no directed edge, so the second flow's
        // arrival must not perturb the first (its component is disjoint).
        let sim = FabricSim::new(Topology::line(4), LinkSpec::cxl3_x16(), RoutingPolicy::Hbr);
        let bytes = 1u64 << 26;
        let est01 = sim.estimate(0, 1, bytes).unwrap();
        let mut eng = Engine::new();
        let first: Rc<RefCell<Option<FlowDone>>> = Rc::new(RefCell::new(None));
        let f = first.clone();
        sim.submit_with(&mut eng, Transfer::new(0, 1, bytes, TrafficClass::Collective), move |_, r| {
            *f.borrow_mut() = Some(r)
        });
        let sim2 = sim.clone();
        eng.schedule_at(est01 * 0.5, move |e| {
            sim2.submit(e, Transfer::new(2, 3, bytes, TrafficClass::Collective));
        });
        eng.run();
        let d = first.borrow().expect("first flow done");
        let rel = (d.latency - est01).abs() / est01;
        assert!(rel < 0.01, "disjoint flow perturbed: latency={} est={est01}", d.latency);
        assert_eq!(sim.completed(), 2);
    }

    /// Shared workload for the aggregation-equivalence checks: `m` equal
    /// flows over the same star route plus one cross flow, all at t=0.
    fn agg_run(policy: AggregationPolicy, m: usize) -> (Vec<f64>, u64, CommTaxLedger) {
        let sim = star_sim(4, RoutingPolicy::Hbr);
        sim.set_aggregation(policy);
        let eps = sim.endpoints();
        let mut eng = Engine::new();
        let done: Rc<RefCell<Vec<FlowDone>>> = Rc::new(RefCell::new(Vec::new()));
        for i in 0..m {
            let d = done.clone();
            let bytes = (1u64 << 22) + (i as u64) * 4096; // distinct sizes
            sim.submit_with(&mut eng, Transfer::new(eps[0], eps[1], bytes, TrafficClass::KvCache), move |_, r| {
                d.borrow_mut().push(r)
            });
        }
        let d = done.clone();
        sim.submit_with(&mut eng, Transfer::new(eps[2], eps[1], 1 << 22, TrafficClass::Activation), move |_, r| {
            d.borrow_mut().push(r)
        });
        eng.run();
        let mut rs: Vec<(FlowId, f64)> = done.borrow().iter().map(|r| (r.id, r.arrival)).collect();
        rs.sort_by_key(|r| r.0);
        (rs.into_iter().map(|(_, a)| a).collect(), sim.aggregated_joins(), sim.ledger())
    }

    #[test]
    fn aggregation_matches_unaggregated_run() {
        let (base, j0, lb) = agg_run(AggregationPolicy::Off, 4);
        let (fused, j1, lf) = agg_run(AggregationPolicy::SameRoute, 4);
        assert_eq!(j0, 0);
        assert_eq!(j1, 3, "three of the four same-route flows must join the first");
        assert_eq!(base.len(), fused.len());
        for (a, b) in base.iter().zip(fused.iter()) {
            let rel = (a - b).abs() / a.max(1.0);
            assert!(rel < 1e-9, "member arrival diverged: {a} vs {b}");
        }
        // ledger attribution is exact, not approximate
        assert_eq!(lb.total_payload, lf.total_payload);
        assert_eq!(lb.class_payload, lf.class_payload);
        assert_eq!(lb.flows, lf.flows);
        assert_eq!(lb.per_link.len(), lf.per_link.len());
        for (a, b) in lb.per_link.iter().zip(lf.per_link.iter()) {
            assert_eq!(a.edge, b.edge);
            assert_eq!(a.payload, b.payload);
            assert_eq!(a.peak_flows, b.peak_flows, "PBR/peak accounting counts members, not aggregates");
        }
    }

    #[test]
    fn aggregation_keys_on_class_and_route() {
        // same pair, different classes: must not fuse
        let sim = star_sim(3, RoutingPolicy::Hbr);
        sim.set_aggregation(AggregationPolicy::SameRoute);
        let eps = sim.endpoints();
        let mut eng = Engine::new();
        sim.submit(&mut eng, Transfer::new(eps[0], eps[1], 1 << 20, TrafficClass::KvCache));
        sim.submit(&mut eng, Transfer::new(eps[0], eps[1], 1 << 20, TrafficClass::Activation));
        sim.submit(&mut eng, Transfer::new(eps[1], eps[0], 1 << 20, TrafficClass::KvCache));
        eng.run();
        assert_eq!(sim.aggregated_joins(), 0);
        assert_eq!(sim.completed(), 3);
    }

    #[test]
    fn aggregate_accepts_midstream_joins() {
        // a member arriving while the aggregate is mid-stream anchors its
        // threshold at the current position and completes with its own bytes
        let sim = star_sim(3, RoutingPolicy::Hbr);
        sim.set_aggregation(AggregationPolicy::SameRoute);
        let eps = sim.endpoints();
        let bytes = 1u64 << 26;
        let est = sim.estimate(eps[0], eps[1], bytes).unwrap();
        let mut eng = Engine::new();
        let done: Rc<RefCell<Vec<FlowDone>>> = Rc::new(RefCell::new(Vec::new()));
        let d = done.clone();
        sim.submit_with(&mut eng, Transfer::new(eps[0], eps[1], bytes, TrafficClass::KvCache), move |_, r| {
            d.borrow_mut().push(r)
        });
        let (sim2, eps2, d2) = (sim.clone(), eps.clone(), done.clone());
        eng.schedule_at(est * 0.5, move |e| {
            sim2.submit_with(e, Transfer::new(eps2[0], eps2[1], bytes, TrafficClass::KvCache), move |_, r| {
                d2.borrow_mut().push(r)
            });
        });
        eng.run();
        assert_eq!(sim.aggregated_joins(), 1);
        let rs = done.borrow();
        assert_eq!(rs.len(), 2);
        // both flows shared the route for the overlap, so each pays tax
        assert!(rs[0].latency > est * 1.2, "first={} est={est}", rs[0].latency);
        assert!(rs[1].latency > est * 1.2, "second={} est={est}", rs[1].latency);
        assert_eq!(sim.active_flows(), 0);
    }

    #[test]
    fn global_fallback_threshold_forces_global_pass() {
        // global_fraction = 0 falls back to the global pass on every event;
        // results must match the default incremental run
        let run = |solver: RateSolver| {
            let sim = star_sim(6, RoutingPolicy::Hbr);
            sim.set_rate_solver(solver);
            let eps = sim.endpoints();
            let mut eng = Engine::new();
            let mut rng = crate::sim::Rng::new(11);
            for _ in 0..30 {
                let (a, b) = (rng.index(6), rng.index(6));
                sim.submit(&mut eng, Transfer::new(eps[a], eps[b], 1 + rng.below(1 << 20), TrafficClass::Collective));
            }
            eng.run();
            (sim.completed(), sim.total_payload(), sim.ledger().contention.sum())
        };
        let (c1, p1, s1) = run(RateSolver::Incremental { global_fraction: 0.0 });
        let (c2, p2, s2) = run(RateSolver::Incremental { global_fraction: 1.0 });
        assert_eq!(c1, c2);
        assert_eq!(p1, p2);
        let rel = (s1 - s2).abs() / s1.abs().max(1.0);
        assert!(rel < 1e-6, "contention diverged: {s1} vs {s2}");
    }

    #[test]
    fn hottest_is_bounded_and_tie_deterministic() {
        let mk = |edge: EdgeId, utilization: f64| LinkUse {
            edge,
            src: 0,
            dst: 1,
            link: "test",
            payload: 1,
            utilization,
            peak_flows: 1,
        };
        let ledger = CommTaxLedger {
            elapsed: 1.0,
            flows: 0,
            total_payload: 0,
            class_payload: [0; TrafficClass::COUNT],
            per_link: vec![mk(0, 0.9), mk(1, 0.5), mk(2, 0.9), mk(3, 0.1), mk(4, 0.5)],
            contention: Summary::new(),
            mean_utilization: 0.0,
            peak_utilization: 0.9,
            mean_active_flows: 0.0,
            peak_active_flows: 0.0,
        };
        assert!(ledger.hottest(0).is_empty());
        let top3: Vec<EdgeId> = ledger.hottest(3).iter().map(|l| l.edge).collect();
        // ties (0.9: edges 0,2; 0.5: edges 1,4) resolve by ascending edge id
        assert_eq!(top3, vec![0, 2, 1]);
        let all: Vec<EdgeId> = ledger.hottest(10).iter().map(|l| l.edge).collect();
        assert_eq!(all, vec![0, 2, 1, 4, 3]);
    }
}
