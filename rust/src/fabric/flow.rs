//! Flow-level, contention-aware fabric simulation on the event engine.
//!
//! The analytic [`super::Fabric`] prices a transfer with closed-form math
//! against per-edge `busy_until` scalars — adequate for back-to-back
//! traffic, but structurally blind to the paper's central object: the
//! *communication tax* that appears when concurrent flows share links.
//! [`FabricSim`] models it directly:
//!
//! * every [`Transfer`] is routed along a concrete edge path in the owned
//!   [`Topology`] (HBR fixed shortest path, or PBR spreading over the
//!   equal-cost set by live flow count);
//! * each directed edge is a shared fluid resource; active flows get
//!   **max-min fair** rates via progressive filling, weighted by each
//!   edge's flit-framing expansion so wire bytes (not payload bytes) are
//!   what saturates a link;
//! * the simulation is **event-driven at flow granularity**: rates only
//!   change when a flow starts or finishes, so we recompute bottleneck
//!   rates at those instants and reschedule the next completion — no
//!   per-flit or per-quantum ticking, which keeps supercluster-scale runs
//!   cheap (work per rate change is `O(active flows × path length)`);
//! * a per-link **communication-tax ledger** (delivered payload bytes,
//!   time-integrated utilization, peak concurrent flows, per-flow
//!   contention delay) is maintained as the run advances and can be
//!   exported into experiment reports and [`crate::coordinator::telemetry`].
//!
//! An *uncontended* flow completes in exactly `Σ hop_latency +
//! max_e wire_time_e(bytes)` — the same figure the analytic
//! [`crate::datacenter::hierarchy::CommPath::time`] produces for the
//! equivalent hardware-mediated path — so the flow model degrades to the
//! closed form when the fabric is idle, and everything above that baseline
//! is measured queueing/contention.
//!
//! Units follow the crate convention: time ns (`f64`), sizes bytes,
//! bandwidth bytes/ns.

use super::link::LinkSpec;
use super::routing::RoutingPolicy;
use super::topology::{NodeId, Topology};
use super::EdgeId;
use crate::sim::stats::TimeWeighted;
use crate::sim::{Engine, SimTime, Summary};
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;
use std::sync::Arc;

/// Identifier of a flow within one [`FabricSim`] (submission order).
pub type FlowId = u64;

/// What a transfer carries — drives per-class ledger accounting so the
/// tax can be attributed (gradient sync vs KV fetch vs activation hop).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TrafficClass {
    /// Collective-communication step (all-reduce chunk, all-to-all shard).
    Collective,
    /// KV-cache movement between accelerator and pool.
    KvCache,
    /// Activation traffic (pipeline/tensor boundaries, prefill→decode).
    Activation,
    /// Parameter/weight movement (loads, rebalancing).
    Parameter,
    /// Small control/metadata messages.
    Control,
    /// Hierarchical-memory tier movement (demotion, promotion, placement
    /// migration) — the §6.3 traffic the tier model used to price analytically.
    Migration,
}

impl TrafficClass {
    /// Number of traffic classes (ledger column count).
    pub const COUNT: usize = 6;

    /// All classes, in ledger column order.
    pub const ALL: [TrafficClass; Self::COUNT] =
        [Self::Collective, Self::KvCache, Self::Activation, Self::Parameter, Self::Control, Self::Migration];

    /// Stable lowercase name for reports.
    pub fn name(self) -> &'static str {
        match self {
            Self::Collective => "collective",
            Self::KvCache => "kvcache",
            Self::Activation => "activation",
            Self::Parameter => "parameter",
            Self::Control => "control",
            Self::Migration => "migration",
        }
    }

    fn index(self) -> usize {
        match self {
            Self::Collective => 0,
            Self::KvCache => 1,
            Self::Activation => 2,
            Self::Parameter => 3,
            Self::Control => 4,
            Self::Migration => 5,
        }
    }
}

/// One transfer request.
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    pub src: NodeId,
    pub dst: NodeId,
    /// Payload bytes (wire expansion applied per edge from its flit format).
    pub bytes: u64,
    pub class: TrafficClass,
}

impl Transfer {
    /// Convenience constructor.
    pub fn new(src: NodeId, dst: NodeId, bytes: u64, class: TrafficClass) -> Self {
        Transfer { src, dst, bytes, class }
    }
}

/// Completion record handed to the submitter's callback.
#[derive(Clone, Copy, Debug)]
pub struct FlowDone {
    pub id: FlowId,
    pub class: TrafficClass,
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: u64,
    /// Submission time (ns).
    pub submitted: SimTime,
    /// Delivery time of the last byte (ns).
    pub arrival: SimTime,
    /// End-to-end latency: `arrival - submitted`.
    pub latency: f64,
    /// Uncontended latency over the same route (hop latencies + bottleneck
    /// wire time) — what the analytic model would have charged.
    pub ideal: f64,
    /// The communication tax on this flow: `latency - ideal` (>= 0 up to
    /// float rounding).
    pub contention: f64,
    /// Hops traversed.
    pub hops: usize,
}

/// Per-link row of the communication-tax ledger.
#[derive(Clone, Debug)]
pub struct LinkUse {
    pub edge: EdgeId,
    pub src: NodeId,
    pub dst: NodeId,
    /// Link technology name (from [`LinkSpec::name`]).
    pub link: &'static str,
    /// Payload bytes delivered across this edge.
    pub payload: u64,
    /// Time-weighted utilization in [0, 1] over the elapsed sim span.
    pub utilization: f64,
    /// Peak number of flows simultaneously routed over this edge.
    pub peak_flows: u32,
}

/// Aggregated communication-tax ledger for one simulation run.
#[derive(Clone, Debug)]
pub struct CommTaxLedger {
    /// Simulated span the utilization figures are normalized over (ns).
    pub elapsed: f64,
    /// Flows completed.
    pub flows: u64,
    /// Total payload bytes delivered.
    pub total_payload: u64,
    /// Payload bytes per traffic class (indexed per [`TrafficClass::ALL`]).
    pub class_payload: [u64; TrafficClass::COUNT],
    /// Every edge that carried traffic, in edge-id order.
    pub per_link: Vec<LinkUse>,
    /// Per-flow contention delay (`latency - ideal`) distribution.
    pub contention: Summary,
    /// Mean utilization over links that carried traffic.
    pub mean_utilization: f64,
    /// Highest per-link utilization.
    pub peak_utilization: f64,
    /// Mean and peak concurrent active flows over time.
    pub mean_active_flows: f64,
    pub peak_active_flows: f64,
}

impl CommTaxLedger {
    /// The `n` busiest links by utilization (ties broken by edge id).
    pub fn hottest(&self, n: usize) -> Vec<&LinkUse> {
        let mut refs: Vec<&LinkUse> = self.per_link.iter().collect();
        refs.sort_by(|a, b| b.utilization.partial_cmp(&a.utilization).unwrap_or(std::cmp::Ordering::Equal));
        refs.truncate(n);
        refs
    }

    /// Payload bytes delivered for one traffic class.
    pub fn class_bytes(&self, class: TrafficClass) -> u64 {
        self.class_payload[class.index()]
    }
}

/// One in-flight (or staged) flow.
struct FlowState {
    class: TrafficClass,
    src: NodeId,
    dst: NodeId,
    bytes: u64,
    /// Edge ids along the route (shares the topology's cached path storage
    /// on the HBR fast path — no per-flow copy).
    path: Arc<Vec<EdgeId>>,
    /// Wire-byte expansion per path edge (`wire_bytes / payload`); the flow
    /// consumes `rate × weight` of an edge's capacity.
    weight: Vec<f64>,
    /// Payload bytes still to stream.
    remaining: f64,
    /// Current max-min fair payload rate (bytes/ns).
    rate: f64,
    /// Predicted completion under the current rate assignment.
    finish_at: SimTime,
    submitted: SimTime,
    /// Uncontended latency over this route.
    ideal: f64,
}

/// Trace record kinds (kept numeric for compact deterministic rendering).
const TRACE_SUBMIT: u8 = 0;
const TRACE_DELIVER: u8 = 1;

struct TraceRec {
    t: SimTime,
    kind: u8,
    id: FlowId,
    src: NodeId,
    dst: NodeId,
    bytes: u64,
}

type DoneCb = Box<dyn FnOnce(&mut Engine, FlowDone)>;

/// Reusable buffers for the progressive-filling pass: rate recomputes run
/// on every flow start/finish (the hot path), so their working vectors are
/// kept across calls instead of reallocated.
#[derive(Default)]
struct RateScratch {
    ids: Vec<FlowId>,
    cap_left: Vec<f64>,
    wsum: Vec<f64>,
    rate: Vec<f64>,
    frozen: Vec<bool>,
    used: Vec<f64>,
}

/// Interior state of the simulator (single-threaded, event-callback shared).
struct FlowNet {
    topo: Topology,
    /// Link spec per directed edge (parallel to the topology edge list).
    links: Vec<LinkSpec>,
    policy: RoutingPolicy,
    /// Flows streaming right now (BTreeMap: deterministic iteration order).
    active: BTreeMap<FlowId, FlowState>,
    /// Flows submitted but still paying the head-of-message hop latency.
    staged: BTreeMap<FlowId, FlowState>,
    pending_cb: HashMap<FlowId, DoneCb>,
    next_id: FlowId,
    /// Generation counter: bumped on every rate recompute so completion
    /// events scheduled under an older rate assignment become no-ops.
    epoch: u64,
    /// Clock of the last state advance.
    last_t: SimTime,
    /// Edges currently carrying flows, with their total wire rate.
    in_use: Vec<(EdgeId, f64)>,
    /// Live flow count per edge (routing signal + peak tracking).
    flows_on_edge: Vec<u32>,
    // ----- ledger -------------------------------------------------------
    edge_payload: Vec<u64>,
    edge_util_ns: Vec<f64>,
    edge_peak: Vec<u32>,
    class_payload: [u64; TrafficClass::COUNT],
    total_payload: u64,
    completed: u64,
    contention: Summary,
    concurrency: TimeWeighted,
    trace: Vec<TraceRec>,
    trace_cap: usize,
    scratch: RateScratch,
}

impl FlowNet {
    fn new(topo: Topology, policy: RoutingPolicy, links: Vec<LinkSpec>) -> Self {
        let ne = links.len();
        FlowNet {
            topo,
            links,
            policy,
            active: BTreeMap::new(),
            staged: BTreeMap::new(),
            pending_cb: HashMap::new(),
            next_id: 0,
            epoch: 0,
            last_t: 0.0,
            in_use: Vec::new(),
            flows_on_edge: vec![0; ne],
            edge_payload: vec![0; ne],
            edge_util_ns: vec![0.0; ne],
            edge_peak: vec![0; ne],
            class_payload: [0; TrafficClass::COUNT],
            total_payload: 0,
            completed: 0,
            contention: Summary::new(),
            concurrency: TimeWeighted::new(),
            trace: Vec::new(),
            trace_cap: 1 << 16,
            scratch: RateScratch::default(),
        }
    }

    /// Pick a route for (src, dst). HBR: the cached shortest path. PBR:
    /// the equal-cost candidate whose most-loaded edge carries the fewest
    /// live flows (deterministic tie-break on candidate order).
    fn route(&self, src: NodeId, dst: NodeId) -> Option<Arc<Vec<EdgeId>>> {
        match self.policy {
            // HBR: share the cache's Arc directly — no copy per flow.
            RoutingPolicy::Hbr => self.topo.shortest_path(src, dst),
            RoutingPolicy::Pbr => {
                let cands = self.topo.equal_cost_paths_cached(src, dst, 8);
                if cands.is_empty() {
                    return None;
                }
                let mut best = 0usize;
                let mut best_key = (u32::MAX, u64::MAX);
                for (i, p) in cands.iter().enumerate() {
                    let peak = p.iter().map(|&e| self.flows_on_edge[e]).max().unwrap_or(0);
                    let sum: u64 = p.iter().map(|&e| self.flows_on_edge[e] as u64).sum();
                    if (peak, sum) < best_key {
                        best_key = (peak, sum);
                        best = i;
                    }
                }
                Some(Arc::new(cands[best].clone()))
            }
        }
    }

    /// Fixed hop latency and bottleneck wire time of a concrete route —
    /// the idle (analytic-equivalent) cost of moving `bytes` over it.
    /// [`FabricSim::estimate`] and flow submission share this, so
    /// `FlowDone::ideal` can never drift from the public estimate.
    fn hop_wire(&self, path: &[EdgeId], bytes: u64) -> (f64, f64) {
        let mut hop = 0.0;
        let mut wire: f64 = 0.0;
        for &e in path {
            hop += self.links[e].hop_latency();
            wire = wire.max(self.links[e].wire_time(bytes));
        }
        (hop, wire)
    }

    /// Stream all active flows forward to `now` and integrate utilization.
    /// The net clock never moves backwards (a fresh engine driving an old
    /// sim resumes from the sim's high-water mark).
    fn advance(&mut self, now: SimTime) {
        let dt = now - self.last_t;
        if dt > 0.0 {
            for f in self.active.values_mut() {
                f.remaining = (f.remaining - f.rate * dt).max(0.0);
            }
            for &(e, wire_rate) in &self.in_use {
                let cap = self.links[e].bw;
                self.edge_util_ns[e] += dt * (wire_rate / cap).min(1.0);
            }
            self.last_t = now;
        }
    }

    /// Progressive-filling max-min fair rate assignment over active flows,
    /// weighted by per-edge wire expansion. O(iterations × flows × hops)
    /// with at most one freeze round per flow.
    fn recompute_rates(&mut self, now: SimTime) {
        self.epoch += 1;
        self.in_use.clear();
        if self.active.is_empty() {
            return;
        }
        let ne = self.links.len();
        // pull the scratch buffers out so the borrow checker sees them as
        // locals, disjoint from `self.active`/`self.links`
        let mut s = std::mem::take(&mut self.scratch);
        s.ids.clear();
        s.ids.extend(self.active.keys().copied());
        s.cap_left.clear();
        s.cap_left.extend(self.links.iter().map(|l| l.bw));
        s.wsum.clear();
        s.wsum.resize(ne, 0.0);
        s.rate.clear();
        s.rate.resize(s.ids.len(), 0.0);
        s.frozen.clear();
        s.frozen.resize(s.ids.len(), false);
        s.used.clear();
        s.used.resize(ne, 0.0);
        let mut left = s.ids.len();
        while left > 0 {
            for w in s.wsum.iter_mut() {
                *w = 0.0;
            }
            for (i, id) in s.ids.iter().enumerate() {
                if s.frozen[i] {
                    continue;
                }
                let f = &self.active[id];
                for (k, &e) in f.path.iter().enumerate() {
                    s.wsum[e] += f.weight[k];
                }
            }
            let mut inc = f64::INFINITY;
            for e in 0..ne {
                if s.wsum[e] > 0.0 {
                    let room = (s.cap_left[e] / s.wsum[e]).max(0.0);
                    if room < inc {
                        inc = room;
                    }
                }
            }
            if !inc.is_finite() {
                break;
            }
            for (i, r) in s.rate.iter_mut().enumerate() {
                if !s.frozen[i] {
                    *r += inc;
                }
            }
            for e in 0..ne {
                if s.wsum[e] > 0.0 {
                    s.cap_left[e] -= inc * s.wsum[e];
                }
            }
            let mut any = false;
            for (i, id) in s.ids.iter().enumerate() {
                if s.frozen[i] {
                    continue;
                }
                let f = &self.active[id];
                if f.path.iter().any(|&e| s.cap_left[e] <= self.links[e].bw * 1e-9) {
                    s.frozen[i] = true;
                    left -= 1;
                    any = true;
                }
            }
            if !any {
                // numerical guard: no link saturated despite finite inc
                break;
            }
        }
        for (i, id) in s.ids.iter().enumerate() {
            let f = self.active.get_mut(id).expect("active flow");
            f.rate = s.rate[i];
            f.finish_at = if f.rate > 0.0 { now + f.remaining / f.rate } else { f64::INFINITY };
            for (k, &e) in f.path.iter().enumerate() {
                s.used[e] += s.rate[i] * f.weight[k];
            }
        }
        for (e, &u) in s.used.iter().enumerate() {
            if u > 0.0 {
                self.in_use.push((e, u));
            }
        }
        self.scratch = s;
    }

    fn next_finish(&self) -> Option<SimTime> {
        let mut t = f64::INFINITY;
        for f in self.active.values() {
            if f.finish_at < t {
                t = f.finish_at;
            }
        }
        if t.is_finite() {
            Some(t)
        } else {
            None
        }
    }

    fn record_trace(&mut self, t: SimTime, kind: u8, id: FlowId, src: NodeId, dst: NodeId, bytes: u64) {
        if self.trace.len() < self.trace_cap {
            self.trace.push(TraceRec { t, kind, id, src, dst, bytes });
        }
    }

    /// Ledger bookkeeping at delivery time.
    fn settle(&mut self, f: &FlowState, id: FlowId, now: SimTime) -> FlowDone {
        for &e in f.path.iter() {
            self.edge_payload[e] += f.bytes;
            self.flows_on_edge[e] = self.flows_on_edge[e].saturating_sub(1);
        }
        self.total_payload += f.bytes;
        self.class_payload[f.class.index()] += f.bytes;
        self.completed += 1;
        let latency = now - f.submitted;
        let contention = (latency - f.ideal).max(0.0);
        self.contention.add(contention);
        self.record_trace(now, TRACE_DELIVER, id, f.src, f.dst, f.bytes);
        FlowDone {
            id,
            class: f.class,
            src: f.src,
            dst: f.dst,
            bytes: f.bytes,
            submitted: f.submitted,
            arrival: now,
            latency,
            ideal: f.ideal,
            contention,
            hops: f.path.len(),
        }
    }
}

/// Flow-level contention-aware fabric simulator. Cheap to clone: clones
/// share the same interior state (the handle is an `Rc`), which is what
/// event callbacks capture.
#[derive(Clone)]
pub struct FabricSim {
    net: Rc<RefCell<FlowNet>>,
}

impl std::fmt::Debug for FabricSim {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.net.try_borrow() {
            Ok(n) => f
                .debug_struct("FabricSim")
                .field("active", &n.active.len())
                .field("completed", &n.completed)
                .field("edges", &n.links.len())
                .finish(),
            Err(_) => f.debug_struct("FabricSim").finish_non_exhaustive(),
        }
    }
}

/// Lifting an analytic [`super::Fabric`] into the flow-level simulator
/// moves its topology and per-edge link-spec table wholesale — the table
/// is built exactly once, whichever substrate prices the traffic first.
/// Heterogeneous assemblies ([`crate::datacenter::cluster::Supercluster`])
/// construct one `Fabric` and lift it, instead of re-running their
/// per-edge spec closure against a second constructor.
impl From<super::Fabric> for FabricSim {
    fn from(fabric: super::Fabric) -> Self {
        let super::Fabric { topo, links, policy, .. } = fabric;
        FabricSim { net: Rc::new(RefCell::new(FlowNet::new(topo, policy, links))) }
    }
}

impl FabricSim {
    /// Homogeneous fabric: every edge of `topo` uses `link`.
    pub fn new(topo: Topology, link: LinkSpec, policy: RoutingPolicy) -> Self {
        Self::new_with(topo, policy, |_, _| link.clone())
    }

    /// Heterogeneous fabric: per-edge link specs chosen by `link_for`.
    /// Delegates to the analytic constructor and lifts the result, so the
    /// two substrates share one spec-table builder.
    pub fn new_with(topo: Topology, policy: RoutingPolicy, link_for: impl Fn(EdgeId, &Topology) -> LinkSpec) -> Self {
        super::Fabric::new_with(topo, policy, link_for).into()
    }

    /// Endpoint node ids of the owned topology.
    pub fn endpoints(&self) -> Vec<NodeId> {
        self.net.borrow().topo.endpoints().to_vec()
    }

    /// Run `f` against the owned topology.
    pub fn with_topology<R>(&self, f: impl FnOnce(&Topology) -> R) -> R {
        f(&self.net.borrow().topo)
    }

    /// Routing policy in force.
    pub fn policy(&self) -> RoutingPolicy {
        self.net.borrow().policy
    }

    /// Link spec of a directed edge (cloned out of the shared state).
    pub fn link(&self, e: EdgeId) -> LinkSpec {
        self.net.borrow().links[e].clone()
    }

    /// The route the current policy would pick right now (edge ids), or
    /// `None` when unreachable. Same selection logic as [`Self::submit`].
    pub fn route(&self, src: NodeId, dst: NodeId) -> Option<Vec<EdgeId>> {
        if src == dst {
            return Some(Vec::new());
        }
        self.net.borrow().route(src, dst).map(|p| p.as_ref().clone())
    }

    /// Whether the current policy can route `src` → `dst`, without copying
    /// a path out (the cheap pre-check for callers that must not lose
    /// their completion callback to an unroutable [`Self::submit_with`]).
    pub fn reachable(&self, src: NodeId, dst: NodeId) -> bool {
        src == dst || self.net.borrow().route(src, dst).is_some()
    }

    /// Flows currently streaming (excludes staged submissions).
    pub fn active_flows(&self) -> usize {
        self.net.borrow().active.len()
    }

    /// Flows delivered so far.
    pub fn completed(&self) -> u64 {
        self.net.borrow().completed
    }

    /// Payload bytes delivered so far.
    pub fn total_payload(&self) -> u64 {
        self.net.borrow().total_payload
    }

    /// Payload bytes delivered across one directed edge so far.
    pub fn edge_payload(&self, e: EdgeId) -> u64 {
        self.net.borrow().edge_payload[e]
    }

    /// Time-weighted utilization of one directed edge over `[0, now]`
    /// (0 before anything has flowed). Normalizing over the caller's clock
    /// — not the last flow event — lets idle stretches decay the figure,
    /// so a dispatcher sampling it long after a burst sees a cool link.
    /// Cheaper than snapshotting the whole [`Self::ledger`] when only a
    /// handful of edges matter per decision.
    pub fn edge_utilization(&self, e: EdgeId, now: SimTime) -> f64 {
        let n = self.net.borrow();
        let span = n.last_t.max(now);
        if span <= 0.0 {
            0.0
        } else {
            (n.edge_util_ns[e] / span).min(1.0)
        }
    }

    /// Analytic uncontended latency over the route the current policy would
    /// pick: `Σ hop_latency + max_e wire_time_e(bytes)`. The flow model
    /// reproduces exactly this figure when the fabric is otherwise idle.
    pub fn estimate(&self, src: NodeId, dst: NodeId, bytes: u64) -> Option<f64> {
        if src == dst {
            return Some(0.0);
        }
        let n = self.net.borrow();
        let path = n.route(src, dst)?;
        let (hop, wire) = n.hop_wire(&path, bytes);
        Some(hop + wire)
    }

    /// Submit a transfer at the engine's current time; `done` fires when the
    /// last byte arrives. Returns `None` (dropping `done`) when no route
    /// exists.
    pub fn submit_with(
        &self,
        eng: &mut Engine,
        tr: Transfer,
        done: impl FnOnce(&mut Engine, FlowDone) + 'static,
    ) -> Option<FlowId> {
        let now = eng.now();
        // Same-node transfers are local copies: free and instant.
        if tr.src == tr.dst {
            let id = {
                let mut n = self.net.borrow_mut();
                let id = n.next_id;
                n.next_id += 1;
                n.completed += 1;
                // keep the ledger's byte columns consistent with its flow
                // count even though no edge is crossed
                n.total_payload += tr.bytes;
                n.class_payload[tr.class.index()] += tr.bytes;
                n.contention.add(0.0);
                n.record_trace(now, TRACE_SUBMIT, id, tr.src, tr.dst, tr.bytes);
                n.record_trace(now, TRACE_DELIVER, id, tr.src, tr.dst, tr.bytes);
                id
            };
            let d = FlowDone {
                id,
                class: tr.class,
                src: tr.src,
                dst: tr.dst,
                bytes: tr.bytes,
                submitted: now,
                arrival: now,
                latency: 0.0,
                ideal: 0.0,
                contention: 0.0,
                hops: 0,
            };
            eng.schedule_in(0.0, move |e| done(e, d));
            return Some(id);
        }
        let (id, hop_lat) = {
            let mut n = self.net.borrow_mut();
            let path = n.route(tr.src, tr.dst)?;
            let (hop, wire) = n.hop_wire(&path, tr.bytes);
            let weight: Vec<f64> = path
                .iter()
                .map(|&e| {
                    let l = &n.links[e];
                    if tr.bytes > 0 { l.wire_bytes(tr.bytes) as f64 / tr.bytes as f64 } else { 1.0 }
                })
                .collect();
            let id = n.next_id;
            n.next_id += 1;
            for &e in path.iter() {
                n.flows_on_edge[e] += 1;
                if n.flows_on_edge[e] > n.edge_peak[e] {
                    n.edge_peak[e] = n.flows_on_edge[e];
                }
            }
            n.record_trace(now, TRACE_SUBMIT, id, tr.src, tr.dst, tr.bytes);
            let state = FlowState {
                class: tr.class,
                src: tr.src,
                dst: tr.dst,
                bytes: tr.bytes,
                path,
                weight,
                remaining: tr.bytes as f64,
                rate: 0.0,
                finish_at: f64::INFINITY,
                submitted: now,
                ideal: hop + wire,
            };
            n.staged.insert(id, state);
            (id, hop)
        };
        self.net.borrow_mut().pending_cb.insert(id, Box::new(done));
        // The message head pays the fixed per-hop latencies up front; the
        // body starts streaming (and competing for bandwidth) after them.
        let net = self.net.clone();
        eng.schedule_in(hop_lat, move |e| Self::activate(net, e, id));
        Some(id)
    }

    /// Submit without a completion callback.
    pub fn submit(&self, eng: &mut Engine, tr: Transfer) -> Option<FlowId> {
        self.submit_with(eng, tr, |_, _| {})
    }

    /// Submit and drive the engine until this flow delivers. Other pending
    /// flows progress naturally while waiting. Returns `None` when no route
    /// exists (or the engine drains without delivery, e.g. a horizon stop).
    pub fn transfer_sync(&self, eng: &mut Engine, tr: Transfer) -> Option<FlowDone> {
        let slot: Rc<RefCell<Option<FlowDone>>> = Rc::new(RefCell::new(None));
        let out = slot.clone();
        self.submit_with(eng, tr, move |_, d| {
            *out.borrow_mut() = Some(d);
        })?;
        // drop the read borrow before stepping: the completion callback
        // needs borrow_mut on the same cell
        loop {
            if slot.borrow().is_some() {
                break;
            }
            if !eng.step() {
                break;
            }
        }
        let d = slot.borrow_mut().take();
        d
    }

    fn activate(net: Rc<RefCell<FlowNet>>, eng: &mut Engine, id: FlowId) {
        let now = eng.now();
        {
            let mut n = net.borrow_mut();
            n.advance(now);
            if let Some(f) = n.staged.remove(&id) {
                n.active.insert(id, f);
                let count = n.active.len() as f64;
                n.concurrency.set(now, count);
                n.recompute_rates(now);
            }
        }
        Self::drive(&net, eng);
    }

    /// Schedule the next completion under the current rate assignment. A
    /// later rate change bumps the epoch, turning this event into a no-op.
    fn drive(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine) {
        let (next, epoch) = {
            let n = net.borrow();
            (n.next_finish(), n.epoch)
        };
        if let Some(t) = next {
            let netc = net.clone();
            eng.schedule_at(t, move |e| {
                let live = netc.borrow().epoch == epoch;
                if live {
                    Self::complete_due(netc, e);
                }
            });
        }
    }

    fn complete_due(net: Rc<RefCell<FlowNet>>, eng: &mut Engine) {
        let now = eng.now();
        let mut done: Vec<(FlowDone, Option<DoneCb>)> = Vec::new();
        {
            let mut n = net.borrow_mut();
            n.advance(now);
            let due: Vec<FlowId> =
                n.active.iter().filter(|(_, f)| f.finish_at <= now + 1e-6).map(|(id, _)| *id).collect();
            for id in due {
                let f = n.active.remove(&id).expect("due flow");
                let d = n.settle(&f, id, now);
                let cb = n.pending_cb.remove(&id);
                done.push((d, cb));
            }
            let count = n.active.len() as f64;
            n.concurrency.set(now, count);
            n.recompute_rates(now);
        }
        for (d, cb) in done {
            if let Some(cb) = cb {
                cb(eng, d);
            }
        }
        Self::drive(&net, eng);
    }

    /// Snapshot the communication-tax ledger.
    pub fn ledger(&self) -> CommTaxLedger {
        let n = self.net.borrow();
        let elapsed = n.last_t.max(1e-9);
        let mut per_link = Vec::new();
        let mut util_sum = 0.0;
        let mut util_peak: f64 = 0.0;
        for e in 0..n.links.len() {
            if n.edge_payload[e] == 0 && n.edge_util_ns[e] == 0.0 {
                continue;
            }
            let (src, dst) = n.topo.edge(e);
            let utilization = (n.edge_util_ns[e] / elapsed).min(1.0);
            util_sum += utilization;
            if utilization > util_peak {
                util_peak = utilization;
            }
            per_link.push(LinkUse {
                edge: e,
                src,
                dst,
                link: n.links[e].name,
                payload: n.edge_payload[e],
                utilization,
                peak_flows: n.edge_peak[e],
            });
        }
        let mean_utilization = if per_link.is_empty() { 0.0 } else { util_sum / per_link.len() as f64 };
        CommTaxLedger {
            elapsed: n.last_t,
            flows: n.completed,
            total_payload: n.total_payload,
            class_payload: n.class_payload,
            per_link,
            contention: n.contention.clone(),
            mean_utilization,
            peak_utilization: util_peak,
            mean_active_flows: n.concurrency.mean_until(n.last_t),
            peak_active_flows: n.concurrency.peak(),
        }
    }

    /// Render the flow event trace as stable text — two runs with the same
    /// inputs produce byte-identical output (the determinism contract).
    pub fn trace_render(&self) -> String {
        let n = self.net.borrow();
        let mut out = String::new();
        for r in &n.trace {
            let kind = if r.kind == TRACE_SUBMIT { "submit" } else { "deliver" };
            out.push_str(&format!(
                "{t:.3} {kind} flow={id} {src}->{dst} bytes={bytes}\n",
                t = r.t,
                id = r.id,
                src = r.src,
                dst = r.dst,
                bytes = r.bytes,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::topology::Topology;

    fn star_sim(n: usize, policy: RoutingPolicy) -> FabricSim {
        FabricSim::new(Topology::star(n), LinkSpec::cxl3_x16(), policy)
    }

    #[test]
    fn idle_flow_matches_analytic_exactly() {
        let sim = star_sim(2, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let bytes = 1u64 << 24;
        let est = sim.estimate(eps[0], eps[1], bytes).unwrap();
        // analytic cross-check against the equivalent 2-hop CommPath
        let path = crate::datacenter::hierarchy::CommPath {
            links: vec![LinkSpec::cxl3_x16(), LinkSpec::cxl3_x16()],
            stack: crate::fabric::netstack::SoftwareStack::hw_mediated(),
        };
        assert!((est - path.time(bytes)).abs() < 1e-6, "est={est} path={}", path.time(bytes));
        let mut eng = Engine::new();
        let d = sim.transfer_sync(&mut eng, Transfer::new(eps[0], eps[1], bytes, TrafficClass::Collective)).unwrap();
        let rel = (d.latency - est).abs() / est;
        assert!(rel < 0.01, "latency={} est={est}", d.latency);
        assert!(d.contention < est * 0.01, "idle flow must pay no tax, got {}", d.contention);
    }

    #[test]
    fn sharing_halves_rate() {
        let sim = star_sim(3, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let bytes = 1u64 << 24;
        let solo = {
            let mut eng = Engine::new();
            sim.transfer_sync(&mut eng, Transfer::new(eps[0], eps[1], bytes, TrafficClass::Collective))
                .unwrap()
                .latency
        };
        // fresh sim: two flows leaving eps[0] at once share the e0->switch edge
        let sim = star_sim(3, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let mut eng = Engine::new();
        let done: Rc<RefCell<Vec<FlowDone>>> = Rc::new(RefCell::new(Vec::new()));
        for &dst in &[eps[1], eps[2]] {
            let d = done.clone();
            sim.submit_with(&mut eng, Transfer::new(eps[0], dst, bytes, TrafficClass::Collective), move |_, r| {
                d.borrow_mut().push(r)
            });
        }
        eng.run();
        let rs = done.borrow();
        assert_eq!(rs.len(), 2);
        for r in rs.iter() {
            assert!(r.latency > 1.8 * solo, "shared={} solo={solo}", r.latency);
            assert!(r.latency < 2.2 * solo, "shared={} solo={solo}", r.latency);
            assert!(r.contention > 0.0);
        }
    }

    #[test]
    fn maxmin_downstream_flow_gets_leftover() {
        // f1: a->b, f2: a->c (share a->sw), f3: d->b (shares sw->b with f1).
        // Max-min: f1 and f2 pinned to 1/2 by a->sw; f3 then also gets 1/2
        // of sw->b. All three finish around 2x the solo wire time.
        let sim = star_sim(4, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let bytes = 1u64 << 24;
        let solo_est = sim.estimate(eps[0], eps[1], bytes).unwrap();
        let mut eng = Engine::new();
        let done: Rc<RefCell<Vec<FlowDone>>> = Rc::new(RefCell::new(Vec::new()));
        for (s, t) in [(0usize, 1usize), (0, 2), (3, 1)] {
            let d = done.clone();
            sim.submit_with(&mut eng, Transfer::new(eps[s], eps[t], bytes, TrafficClass::Collective), move |_, r| {
                d.borrow_mut().push(r)
            });
        }
        eng.run();
        let rs = done.borrow();
        assert_eq!(rs.len(), 3);
        for r in rs.iter() {
            assert!(r.latency > 1.5 * solo_est, "latency={} solo={solo_est}", r.latency);
            assert!(r.latency < 2.5 * solo_est, "latency={} solo={solo_est}", r.latency);
        }
    }

    #[test]
    fn pbr_spreads_over_planes_hbr_contends() {
        let run = |policy| {
            let sim = FabricSim::new(Topology::single_clos(4, 2), LinkSpec::cxl3_x16(), policy);
            let eps = sim.endpoints();
            let mut eng = Engine::new();
            let worst: Rc<RefCell<f64>> = Rc::new(RefCell::new(0.0));
            for _ in 0..2 {
                let w = worst.clone();
                sim.submit_with(&mut eng, Transfer::new(eps[0], eps[1], 1 << 24, TrafficClass::Collective), move |_, r| {
                    let mut m = w.borrow_mut();
                    if r.latency > *m {
                        *m = r.latency;
                    }
                });
            }
            eng.run();
            let v = *worst.borrow();
            v
        };
        let hbr = run(RoutingPolicy::Hbr);
        let pbr = run(RoutingPolicy::Pbr);
        assert!(hbr > 1.5 * pbr, "hbr={hbr} pbr={pbr} (PBR should use the idle plane)");
    }

    #[test]
    fn ledger_conserves_bytes() {
        let sim = star_sim(4, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let mut eng = Engine::new();
        let flows = [(0usize, 1usize, 1000u64), (1, 2, 2000), (2, 3, 3000), (3, 0, 500)];
        for &(s, t, b) in &flows {
            sim.submit(&mut eng, Transfer::new(eps[s], eps[t], b, TrafficClass::KvCache));
        }
        eng.run();
        let ledger = sim.ledger();
        let demand: u64 = flows.iter().map(|f| f.2).sum();
        assert_eq!(ledger.total_payload, demand);
        // every flow crosses 2 edges in a star, so per-link sum is 2x demand
        let per_link: u64 = ledger.per_link.iter().map(|l| l.payload).sum();
        assert_eq!(per_link, 2 * demand);
        assert_eq!(ledger.flows, flows.len() as u64);
        assert_eq!(ledger.class_payload[TrafficClass::KvCache.index()], demand);
        assert!(ledger.peak_utilization > 0.0 && ledger.peak_utilization <= 1.0);
    }

    #[test]
    fn same_node_transfer_is_free() {
        let sim = star_sim(2, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let mut eng = Engine::new();
        let d = sim.transfer_sync(&mut eng, Transfer::new(eps[0], eps[0], 1 << 20, TrafficClass::Control)).unwrap();
        assert_eq!(d.latency, 0.0);
        assert_eq!(d.hops, 0);
    }

    #[test]
    fn unreachable_returns_none() {
        let mut topo = Topology::empty(crate::fabric::topology::TopologyKind::Custom);
        let a = topo.add_node(crate::fabric::topology::NodeKind::Endpoint);
        let b = topo.add_node(crate::fabric::topology::NodeKind::Endpoint);
        let sim = FabricSim::new(topo, LinkSpec::cxl3_x16(), RoutingPolicy::Hbr);
        let mut eng = Engine::new();
        assert!(sim.submit(&mut eng, Transfer::new(a, b, 64, TrafficClass::Control)).is_none());
        assert!(sim.estimate(a, b, 64).is_none());
    }

    #[test]
    fn trace_is_deterministic() {
        let run = || {
            let sim = star_sim(6, RoutingPolicy::Pbr);
            let eps = sim.endpoints();
            let mut eng = Engine::new();
            let mut rng = crate::sim::Rng::new(7);
            for _ in 0..40 {
                let a = rng.index(6);
                let b = rng.index(6);
                sim.submit(&mut eng, Transfer::new(eps[a], eps[b], 1 + rng.below(1 << 20), TrafficClass::Collective));
            }
            eng.run();
            (sim.trace_render(), sim.total_payload())
        };
        let (t1, p1) = run();
        let (t2, p2) = run();
        assert_eq!(t1, t2, "trace must be byte-identical across runs");
        assert_eq!(p1, p2);
        assert!(!t1.is_empty());
    }

    #[test]
    fn staggered_flows_reschedule_completions() {
        // A second flow arriving mid-stream slows the first one down: the
        // first flow's completion must be pushed later than its idle
        // estimate, proving completion events are rescheduled on rate change.
        let sim = star_sim(3, RoutingPolicy::Hbr);
        let eps = sim.endpoints();
        let bytes = 1u64 << 26; // 64 MiB: long enough to overlap
        let est = sim.estimate(eps[0], eps[1], bytes).unwrap();
        let mut eng = Engine::new();
        let first: Rc<RefCell<Option<FlowDone>>> = Rc::new(RefCell::new(None));
        let f = first.clone();
        sim.submit_with(&mut eng, Transfer::new(eps[0], eps[1], bytes, TrafficClass::Collective), move |_, r| {
            *f.borrow_mut() = Some(r)
        });
        // inject the competitor halfway through the first flow
        let sim2 = sim.clone();
        let eps2 = eps.clone();
        eng.schedule_at(est * 0.5, move |e| {
            sim2.submit(e, Transfer::new(eps2[0], eps2[2], bytes, TrafficClass::Collective));
        });
        eng.run();
        let d = first.borrow().expect("first flow done");
        assert!(d.latency > 1.3 * est, "latency={} est={est}", d.latency);
        assert!(d.latency < 1.7 * est, "latency={} est={est}", d.latency);
    }
}
