//! Topology builders and graph plumbing (Fig 29, Fig 30, Fig 41).
//!
//! A [`Topology`] is a directed graph of endpoints (accelerators, CPUs,
//! memory devices) and switches. Builders cover every shape the paper
//! discusses:
//!
//! * `single_clos` — the single-hop Clos used by NVLink/NVSwitch and UALink
//!   (every endpoint attaches to every switch plane; any two endpoints are
//!   two hops apart).
//! * `multi_clos` — multi-level switch cascading enabled by CXL 3.0.
//! * `torus3d` — 3D-Torus direct network (Fig 29b).
//! * `dragonfly` — fully-connected local groups + global links (Fig 29c).
//! * `fully_connected` — switchless accelerator cluster with integrated CXL
//!   switching logic (Fig 30a).
//! * `spine_leaf` — conventional scale-out data-center network (§3.3).
//! * `star` / `line` — degenerate helpers for tests and rack models.

use std::collections::{HashMap, VecDeque};
use std::hash::{BuildHasherDefault, Hasher};
use std::sync::{Arc, RwLock};

/// Multiply-shift hasher for the (src, dst) route caches — SipHash showed
/// up in the §Perf transfer-path profile; route keys are small integers so
/// a Fibonacci-multiply hash is collision-adequate and ~4x cheaper.
#[derive(Default)]
pub struct PairHasher(u64);

impl Hasher for PairHasher {
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x9E3779B97F4A7C15);
        }
    }
    fn write_usize(&mut self, v: usize) {
        self.0 = (self.0.rotate_left(32) ^ v as u64).wrapping_mul(0x9E3779B97F4A7C15);
    }
}

// detlint: allow(hash-order) -- fixed (non-random) PairHasher and keyed-lookup-only use: both caches memoize per-pair route results and are never iterated
type PairMap<V> = HashMap<(NodeId, NodeId), V, BuildHasherDefault<PairHasher>>;

/// Node identifier within a topology.
pub type NodeId = usize;

/// What a node is.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NodeKind {
    /// Traffic source/sink: accelerator, CPU, memory device, NIC…
    Endpoint,
    /// Forwarding element.
    Switch,
}

/// Shape tag (reporting only).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    Line,
    Star,
    FullyConnected,
    SingleClos,
    MultiClos,
    Torus3D,
    DragonFly,
    SpineLeaf,
    Custom,
}

/// Directed graph with BFS route cache.
///
/// The route/ECMP caches sit behind `RwLock`s and hand out `Arc`s, so a
/// built `Topology` is `Send + Sync`: experiments can fan shared read-only
/// topologies out across threads while still enjoying warm caches. Once a
/// pair is warm the lookup takes only a shared read lock — concurrent
/// readers (parallel component solves, hot submit loops) never serialize
/// on each other; only the one-time fill per pair takes the write lock,
/// and a racing double-compute is benign (BFS is deterministic, last
/// insert wins with an identical value). Hot-path callers hold the
/// returned `Arc` per flow instead of re-resolving, and `perf_hotpath`
/// tracks the transfer-path cost.
#[derive(Debug)]
pub struct Topology {
    kind: TopologyKind,
    nodes: Vec<NodeKind>,
    /// Directed edges (src, dst).
    edges: Vec<(NodeId, NodeId)>,
    /// adjacency: node -> [(neighbor, edge id)]
    adj: Vec<Vec<(NodeId, usize)>>,
    endpoints: Vec<NodeId>,
    // detlint: allow(hash-order) -- per-pair memo cache, get/insert by (src, dst) key only
    route_cache: RwLock<PairMap<Option<Arc<Vec<usize>>>>>,
    /// Equal-cost candidate sets for PBR (computed once per pair).
    // detlint: allow(hash-order) -- per-pair memo cache, get/insert by (src, dst) key only
    ecmp_cache: RwLock<PairMap<Arc<Vec<Vec<usize>>>>>,
}

impl Topology {
    /// Empty topology of a given kind.
    pub fn empty(kind: TopologyKind) -> Self {
        Topology {
            kind,
            nodes: Vec::new(),
            edges: Vec::new(),
            adj: Vec::new(),
            endpoints: Vec::new(),
            // detlint: allow(hash-order) -- ctor of the keyed-lookup-only cache waived at its declaration
            route_cache: RwLock::new(HashMap::default()),
            // detlint: allow(hash-order) -- ctor of the keyed-lookup-only cache waived at its declaration
            ecmp_cache: RwLock::new(HashMap::default()),
        }
    }

    /// Add a node, returning its id.
    pub fn add_node(&mut self, kind: NodeKind) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(kind);
        self.adj.push(Vec::new());
        if kind == NodeKind::Endpoint {
            self.endpoints.push(id);
        }
        id
    }

    /// Add a bidirectional link (two directed edges). Returns (fwd, rev).
    pub fn add_link(&mut self, a: NodeId, b: NodeId) -> (usize, usize) {
        let fwd = self.edges.len();
        self.edges.push((a, b));
        self.adj[a].push((b, fwd));
        let rev = self.edges.len();
        self.edges.push((b, a));
        self.adj[b].push((a, rev));
        self.route_cache.write().expect("route cache").clear();
        self.ecmp_cache.write().expect("ecmp cache").clear();
        (fwd, rev)
    }

    /// Kind tag.
    pub fn kind(&self) -> TopologyKind {
        self.kind
    }

    /// All node kinds, indexed by `NodeId`.
    pub fn node_kind(&self, n: NodeId) -> NodeKind {
        self.nodes[n]
    }

    /// Total node count.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Directed edge count.
    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    /// Endpoints (traffic sources/sinks).
    pub fn endpoints(&self) -> &[NodeId] {
        &self.endpoints
    }

    /// Switch count.
    pub fn switch_count(&self) -> usize {
        self.nodes.iter().filter(|k| **k == NodeKind::Switch).count()
    }

    /// Endpoints of a directed edge.
    pub fn edge(&self, e: usize) -> (NodeId, NodeId) {
        self.edges[e]
    }

    /// Neighbors of a node with their edge ids.
    pub fn neighbors(&self, n: NodeId) -> &[(NodeId, usize)] {
        &self.adj[n]
    }

    /// BFS shortest path (deterministic: neighbor insertion order breaks
    /// ties). Cached; the returned Arc avoids per-call path clones on the
    /// hot transfer path (§Perf). Edge ids along the path.
    pub fn shortest_path(&self, src: NodeId, dst: NodeId) -> Option<Arc<Vec<usize>>> {
        if src == dst {
            return Some(Arc::new(Vec::new()));
        }
        if let Some(hit) = self.route_cache.read().expect("route cache").get(&(src, dst)) {
            return hit.clone();
        }
        // miss: compute outside any lock, then take the write lock only to
        // publish (a racing duplicate compute is deterministic-identical)
        let path = self.bfs(src, dst).map(Arc::new);
        self.route_cache.write().expect("route cache").insert((src, dst), path.clone());
        path
    }

    fn bfs(&self, src: NodeId, dst: NodeId) -> Option<Vec<usize>> {
        let mut prev: Vec<Option<(NodeId, usize)>> = vec![None; self.nodes.len()];
        let mut seen = vec![false; self.nodes.len()];
        let mut q = VecDeque::new();
        seen[src] = true;
        q.push_back(src);
        while let Some(u) = q.pop_front() {
            if u == dst {
                break;
            }
            for &(v, e) in &self.adj[u] {
                // Traffic must not transit *through* a foreign endpoint.
                if !seen[v] && (v == dst || self.nodes[v] == NodeKind::Switch) {
                    seen[v] = true;
                    prev[v] = Some((u, e));
                    q.push_back(v);
                }
            }
        }
        if !seen[dst] {
            return None;
        }
        let mut path = Vec::new();
        let mut cur = dst;
        while let Some((p, e)) = prev[cur] {
            path.push(e);
            cur = p;
        }
        path.reverse();
        Some(path)
    }

    /// Cached equal-cost candidate sets for PBR: the path *set* per
    /// (src, dst) is static, only the congestion-based choice among them is
    /// dynamic, so the DFS runs once per pair (§Perf optimization — this
    /// took PBR routing from 0.63 to HBR-class M transfers/s).
    pub fn equal_cost_paths_cached(&self, src: NodeId, dst: NodeId, cap: usize) -> Arc<Vec<Vec<usize>>> {
        if let Some(hit) = self.ecmp_cache.read().expect("ecmp cache").get(&(src, dst)) {
            return hit.clone();
        }
        let paths = Arc::new(self.equal_cost_paths(src, dst, cap));
        self.ecmp_cache.write().expect("ecmp cache").insert((src, dst), paths.clone());
        paths
    }

    /// All equal-length shortest paths from src to dst (bounded at `cap`
    /// alternatives) — used by PBR congestion-aware routing.
    pub fn equal_cost_paths(&self, src: NodeId, dst: NodeId, cap: usize) -> Vec<Vec<usize>> {
        let Some(base) = self.shortest_path(src, dst) else {
            return Vec::new();
        };
        let base = base.as_ref().clone();
        let target = base.len();
        let mut out = Vec::new();
        // DFS bounded by shortest length; fine for the radices we model.
        let mut stack: Vec<(NodeId, Vec<usize>)> = vec![(src, Vec::new())];
        while let Some((u, path)) = stack.pop() {
            if out.len() >= cap {
                break;
            }
            if path.len() > target {
                continue;
            }
            if u == dst && path.len() == target {
                out.push(path);
                continue;
            }
            if path.len() == target {
                continue;
            }
            for &(v, e) in &self.adj[u] {
                if v != dst && self.nodes[v] == NodeKind::Endpoint {
                    continue;
                }
                // avoid revisiting nodes on this path
                let revisit = path.iter().any(|&pe| {
                    let (a, b) = self.edges[pe];
                    a == v || b == v
                });
                if revisit || v == src {
                    continue;
                }
                let mut p2 = path.clone();
                p2.push(e);
                stack.push((v, p2));
            }
        }
        if out.is_empty() {
            out.push(base);
        }
        out
    }

    /// Mean hop count over all endpoint pairs (sampled when large).
    pub fn mean_hops(&self) -> f64 {
        let eps = &self.endpoints;
        if eps.len() < 2 {
            return 0.0;
        }
        let mut total = 0usize;
        let mut pairs = 0usize;
        let stride = (eps.len() * eps.len() / 4096).max(1);
        let mut k = 0usize;
        for (i, &a) in eps.iter().enumerate() {
            for &b in eps.iter().skip(i + 1) {
                k += 1;
                if k % stride != 0 {
                    continue;
                }
                if let Some(p) = self.shortest_path(a, b) {
                    total += p.len();
                    pairs += 1;
                }
            }
        }
        if pairs == 0 {
            0.0
        } else {
            total as f64 / pairs as f64
        }
    }

    // ----- builders -------------------------------------------------------

    /// Endpoints chained in a line (test helper; only adjacent pairs can
    /// communicate since traffic cannot transit foreign endpoints).
    pub fn line(n: usize) -> Topology {
        let mut t = Topology::empty(TopologyKind::Line);
        let ids: Vec<_> = (0..n).map(|_| t.add_node(NodeKind::Endpoint)).collect();
        for w in ids.windows(2) {
            t.add_link(w[0], w[1]);
        }
        t
    }

    /// Two endpoints joined by a chain of `switches` switches (test helper
    /// for hop-count scaling). Endpoint ids are 0 and 1.
    pub fn switch_chain(switches: usize) -> Topology {
        let mut t = Topology::empty(TopologyKind::Custom);
        let a = t.add_node(NodeKind::Endpoint);
        let b = t.add_node(NodeKind::Endpoint);
        let mut prev = a;
        for _ in 0..switches {
            let s = t.add_node(NodeKind::Switch);
            t.add_link(prev, s);
            prev = s;
        }
        t.add_link(prev, b);
        t
    }

    /// `n` endpoints on one crossbar switch.
    pub fn star(n: usize) -> Topology {
        let mut t = Topology::empty(TopologyKind::Star);
        let sw = t.add_node(NodeKind::Switch);
        for _ in 0..n {
            let e = t.add_node(NodeKind::Endpoint);
            t.add_link(e, sw);
        }
        t
    }

    /// Switchless fully-connected accelerator cluster (Fig 30a): every pair
    /// of endpoints gets a direct link.
    pub fn fully_connected(n: usize) -> Topology {
        let mut t = Topology::empty(TopologyKind::FullyConnected);
        let ids: Vec<_> = (0..n).map(|_| t.add_node(NodeKind::Endpoint)).collect();
        for i in 0..n {
            for j in (i + 1)..n {
                t.add_link(ids[i], ids[j]);
            }
        }
        t
    }

    /// Single-hop Clos (NVLink/UALink style): `n` endpoints each wired to
    /// all of `planes` parallel crossbar switches; any pair is 2 hops apart.
    pub fn single_clos(n: usize, planes: usize) -> Topology {
        let mut t = Topology::empty(TopologyKind::SingleClos);
        let sws: Vec<_> = (0..planes.max(1)).map(|_| t.add_node(NodeKind::Switch)).collect();
        for _ in 0..n {
            let e = t.add_node(NodeKind::Endpoint);
            for &sw in &sws {
                t.add_link(e, sw);
            }
        }
        t
    }

    /// Two-level Clos / leaf-spine switch cascade (CXL 3.0 multi-level
    /// switching): endpoints attach to leaves (`per_leaf` each); every leaf
    /// attaches to every spine.
    pub fn multi_clos(n: usize, per_leaf: usize, spines: usize) -> Topology {
        let mut t = Topology::empty(TopologyKind::MultiClos);
        let n_leaves = n.div_ceil(per_leaf.max(1));
        let spine_ids: Vec<_> = (0..spines.max(1)).map(|_| t.add_node(NodeKind::Switch)).collect();
        let mut placed = 0;
        for _ in 0..n_leaves {
            let leaf = t.add_node(NodeKind::Switch);
            for &s in &spine_ids {
                t.add_link(leaf, s);
            }
            for _ in 0..per_leaf {
                if placed >= n {
                    break;
                }
                let e = t.add_node(NodeKind::Endpoint);
                t.add_link(e, leaf);
                placed += 1;
            }
        }
        t
    }

    /// Three-level Clos: pods of two-level Clos joined by core switches
    /// (building-scale fat-tree, §3.3).
    pub fn three_level_clos(n: usize, per_leaf: usize, leaves_per_pod: usize, cores: usize) -> Topology {
        let mut t = Topology::empty(TopologyKind::MultiClos);
        let core_ids: Vec<_> = (0..cores.max(1)).map(|_| t.add_node(NodeKind::Switch)).collect();
        let per_pod = per_leaf * leaves_per_pod;
        let n_pods = n.div_ceil(per_pod.max(1));
        let mut placed = 0;
        for _ in 0..n_pods {
            // pod spine connects up to all cores
            let pod_spine = t.add_node(NodeKind::Switch);
            for &c in &core_ids {
                t.add_link(pod_spine, c);
            }
            for _ in 0..leaves_per_pod {
                let leaf = t.add_node(NodeKind::Switch);
                t.add_link(leaf, pod_spine);
                for _ in 0..per_leaf {
                    if placed >= n {
                        break;
                    }
                    let e = t.add_node(NodeKind::Endpoint);
                    t.add_link(e, leaf);
                    placed += 1;
                }
            }
        }
        t
    }

    /// 3D-Torus (Fig 29b): `dx*dy*dz` endpoints, each with an integrated
    /// router, wrap-around links along each dimension.
    pub fn torus3d(dx: usize, dy: usize, dz: usize) -> Topology {
        let mut t = Topology::empty(TopologyKind::Torus3D);
        let idx = |x: usize, y: usize, z: usize| -> usize { (z * dy + y) * dx + x };
        // In a direct network every node both computes and routes; we model
        // that as an endpoint fused with a router, so endpoint-transit is
        // allowed by adding an explicit router node per endpoint.
        let mut routers = Vec::with_capacity(dx * dy * dz);
        for _ in 0..dx * dy * dz {
            let r = t.add_node(NodeKind::Switch);
            let e = t.add_node(NodeKind::Endpoint);
            t.add_link(e, r);
            routers.push(r);
        }
        for z in 0..dz {
            for y in 0..dy {
                for x in 0..dx {
                    let r = routers[idx(x, y, z)];
                    if dx > 1 {
                        t.add_link(r, routers[idx((x + 1) % dx, y, z)]);
                    }
                    if dy > 1 {
                        t.add_link(r, routers[idx(x, (y + 1) % dy, z)]);
                    }
                    if dz > 1 {
                        t.add_link(r, routers[idx(x, y, (z + 1) % dz)]);
                    }
                }
            }
        }
        t
    }

    /// DragonFly (Fig 29c): `groups` groups of `routers_per_group` routers;
    /// routers within a group fully connected; one endpoint per router; each
    /// pair of groups joined by one global link.
    pub fn dragonfly(groups: usize, routers_per_group: usize) -> Topology {
        let mut t = Topology::empty(TopologyKind::DragonFly);
        let mut group_routers: Vec<Vec<NodeId>> = Vec::new();
        for _ in 0..groups {
            let rs: Vec<_> = (0..routers_per_group)
                .map(|_| {
                    let r = t.add_node(NodeKind::Switch);
                    let e = t.add_node(NodeKind::Endpoint);
                    t.add_link(e, r);
                    r
                })
                .collect();
            for i in 0..rs.len() {
                for j in (i + 1)..rs.len() {
                    t.add_link(rs[i], rs[j]);
                }
            }
            group_routers.push(rs);
        }
        // one global link between each pair of groups, spread across routers
        for g1 in 0..groups {
            for g2 in (g1 + 1)..groups {
                let r1 = group_routers[g1][g2 % routers_per_group];
                let r2 = group_routers[g2][g1 % routers_per_group];
                t.add_link(r1, r2);
            }
        }
        t
    }

    /// Spine-leaf scale-out network: `racks` ToR leaves with
    /// `nodes_per_rack` endpoints each, all leaves to all spines (§3.3).
    pub fn spine_leaf(racks: usize, nodes_per_rack: usize, spines: usize) -> Topology {
        let mut t = Topology::empty(TopologyKind::SpineLeaf);
        let spine_ids: Vec<_> = (0..spines.max(1)).map(|_| t.add_node(NodeKind::Switch)).collect();
        for _ in 0..racks {
            let tor = t.add_node(NodeKind::Switch);
            for &s in &spine_ids {
                t.add_link(tor, s);
            }
            for _ in 0..nodes_per_rack {
                let e = t.add_node(NodeKind::Endpoint);
                t.add_link(e, tor);
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_path_lengths() {
        let t = Topology::line(5);
        assert_eq!(t.shortest_path(0, 1).unwrap().len(), 1);
        assert_eq!(t.shortest_path(0, 0).unwrap().len(), 0);
    }

    #[test]
    fn switch_chain_hop_counts() {
        let t = Topology::switch_chain(3);
        assert_eq!(t.shortest_path(0, 1).unwrap().len(), 4);
    }

    #[test]
    fn star_two_hops() {
        let t = Topology::star(8);
        let eps = t.endpoints().to_vec();
        assert_eq!(t.switch_count(), 1);
        assert_eq!(t.shortest_path(eps[0], eps[7]).unwrap().len(), 2);
    }

    #[test]
    fn fully_connected_one_hop() {
        let t = Topology::fully_connected(6);
        let eps = t.endpoints().to_vec();
        for i in 0..6 {
            for j in 0..6 {
                if i != j {
                    assert_eq!(t.shortest_path(eps[i], eps[j]).unwrap().len(), 1);
                }
            }
        }
        assert_eq!(t.switch_count(), 0);
        // n*(n-1) directed edges
        assert_eq!(t.edge_count(), 6 * 5);
    }

    #[test]
    fn single_clos_is_two_hops_any_pair() {
        let t = Topology::single_clos(72, 9);
        let eps = t.endpoints().to_vec();
        assert_eq!(t.switch_count(), 9);
        assert_eq!(t.shortest_path(eps[0], eps[71]).unwrap().len(), 2);
        assert!((t.mean_hops() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn multi_clos_cascade_four_hops_across_leaves() {
        let t = Topology::multi_clos(64, 16, 4);
        let eps = t.endpoints().to_vec();
        // same leaf: 2 hops; across leaves: 4 hops (ep-leaf-spine-leaf-ep)
        assert_eq!(t.shortest_path(eps[0], eps[1]).unwrap().len(), 2);
        assert_eq!(t.shortest_path(eps[0], eps[63]).unwrap().len(), 4);
    }

    #[test]
    fn three_level_clos_reaches_across_pods() {
        let t = Topology::three_level_clos(128, 8, 4, 4);
        let eps = t.endpoints().to_vec();
        // across pods: ep-leaf-podspine-core-podspine-leaf-ep = 6 hops
        assert_eq!(t.shortest_path(eps[0], eps[127]).unwrap().len(), 6);
    }

    #[test]
    fn torus_wraps_around() {
        let t = Topology::torus3d(4, 4, 4);
        assert_eq!(t.endpoints().len(), 64);
        assert_eq!(t.switch_count(), 64);
        let eps = t.endpoints().to_vec();
        // farthest node in a 4x4x4 torus: 2+2+2 router hops + 2 ep links = 8
        let far = t.shortest_path(eps[0], eps[63]).unwrap().len();
        assert!(far <= 8, "far={far}");
    }

    #[test]
    fn dragonfly_three_switch_hops_max() {
        let t = Topology::dragonfly(6, 4);
        let eps = t.endpoints().to_vec();
        let mut max = 0;
        for &a in eps.iter().take(8) {
            for &b in eps.iter().rev().take(8) {
                if a != b {
                    max = max.max(t.shortest_path(a, b).unwrap().len());
                }
            }
        }
        // ep->r (1) + ≤3 router hops + r->ep (1)
        assert!(max <= 5, "max={max}");
    }

    #[test]
    fn spine_leaf_cross_rack_four_hops() {
        let t = Topology::spine_leaf(4, 8, 2);
        let eps = t.endpoints().to_vec();
        assert_eq!(t.shortest_path(eps[0], eps[31]).unwrap().len(), 4);
    }

    #[test]
    fn no_transit_through_endpoints() {
        // line of endpoints: path 0->2 must pass through endpoint 1 — but
        // endpoint transit is forbidden, so the only allowed route is if 1 is
        // the destination. For a line this means 0->2 is unreachable... which
        // is the correct semantic for endpoint-only chains; real topologies
        // use switches. Line builder is only for adjacent-pair tests.
        let t = Topology::line(3);
        assert!(t.shortest_path(0, 2).is_none());
        assert!(t.shortest_path(0, 1).is_some());
    }

    #[test]
    fn equal_cost_paths_in_clos() {
        let t = Topology::single_clos(8, 4);
        let eps = t.endpoints().to_vec();
        let paths = t.equal_cost_paths(eps[0], eps[1], 8);
        // one 2-hop path per plane
        assert_eq!(paths.len(), 4);
        for p in &paths {
            assert_eq!(p.len(), 2);
        }
    }

    #[test]
    fn topology_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Topology>();
    }

    #[test]
    fn route_cache_is_shared_across_threads() {
        let t = Arc::new(Topology::single_clos(16, 4));
        let mut handles = Vec::new();
        for k in 0..4usize {
            let tc = t.clone();
            handles.push(std::thread::spawn(move || {
                let eps = tc.endpoints().to_vec();
                let mut total = 0usize;
                for i in 0..eps.len() {
                    let j = (i + k + 1) % eps.len();
                    if i != j {
                        total += tc.shortest_path(eps[i], eps[j]).unwrap().len();
                    }
                }
                total
            }));
        }
        for h in handles {
            assert!(h.join().unwrap() > 0);
        }
    }

    #[test]
    fn fig29_switch_count_scaling() {
        // Fig 29: Clos needs multi-stage switches; torus/dragonfly embed
        // routing in nodes. Check relative switch counts at n=64.
        let clos = Topology::multi_clos(64, 8, 4);
        let torus = Topology::torus3d(4, 4, 4);
        let df = Topology::dragonfly(8, 8);
        assert!(clos.switch_count() < torus.switch_count());
        assert_eq!(df.switch_count(), 64);
    }
}
