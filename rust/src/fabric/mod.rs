//! Interconnect fabric models.
//!
//! This module implements every interconnect technology the paper discusses
//! (Table 3): CXL 1.0/2.0/3.0 with HBR/PBR flits and routing, NVLink 5.0 and
//! NVLink-C2C, UALink 1.0, PCIe Gen5/6, and the long-distance scale-out
//! fabrics (Ethernet, InfiniBand) including the *software* overhead of
//! RDMA/TCP stacks that §4.1 identifies as the root of the communication
//! tax. On top of the link models sit switch models, topology builders
//! (single-/multi-level Clos, 3D-Torus, DragonFly, fully-connected,
//! spine-leaf — Fig 29/41), and routing policies (HBR vs PBR — Table 1).
//!
//! The [`Fabric`] type combines a topology with link/switch parameters and a
//! per-edge contention model, exposing `transfer()` for the workload layer.
//!
//! Two pricing substrates coexist:
//!
//! * [`Fabric`] — closed-form per-transfer math against `busy_until`
//!   scalars; fast analytic estimation (idle-fabric assumption).
//! * [`flow::FabricSim`] — the flow-level, contention-aware simulator on
//!   [`crate::sim::Engine`]: concurrent transfers share link bandwidth
//!   max-min fairly, so queueing (the paper's communication tax) is a
//!   measured output, with a per-link utilization ledger.

pub mod cxl;
pub mod flit;
pub mod flow;
pub mod link;
pub mod minheap;
pub mod netstack;
pub mod routing;
pub mod switch;
pub mod topology;

pub use cxl::{CxlProtocol, CxlStack, CxlVersion};
pub use flit::FlitFormat;
pub use flow::{
    AdmissionBatching, AggregationPolicy, CommTaxLedger, FabricSim, FlowDone, FlowId, LinkUse, RateSolver,
    TrafficClass, Transfer,
};
pub use link::{LinkClass, LinkSpec};
pub use netstack::SoftwareStack;
pub use routing::RoutingPolicy;
pub use switch::SwitchSpec;
pub use topology::{NodeId, NodeKind, Topology, TopologyKind};

use crate::sim::SimTime;

/// Identifier of a directed edge within a [`Fabric`].
pub type EdgeId = usize;

/// A fabric: topology + per-edge link specs + contention state.
///
/// The transfer model is cut-through per hop: a message pays the
/// propagation/processing latency of every hop once, plus wire
/// (serialization) time on its *bottleneck* edge, plus queueing delay on any
/// edge that is still busy with earlier traffic. Protocol framing expands
/// payload bytes into wire bytes per the edge's flit format.
#[derive(Debug)]
pub struct Fabric {
    topo: Topology,
    /// Link spec per directed edge (parallel to `topo.edges`).
    links: Vec<LinkSpec>,
    /// Earliest time each directed edge is free.
    busy_until: Vec<SimTime>,
    /// Total payload bytes carried per edge (for utilization accounting).
    carried: Vec<u64>,
    policy: RoutingPolicy,
    /// Total payload bytes transferred through the fabric.
    total_payload: u64,
    /// Total wire bytes (payload × framing expansion) transferred.
    total_wire: u64,
    transfers: u64,
}

/// Outcome of a single fabric transfer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransferResult {
    /// Time the last byte arrives at the destination.
    pub arrival: SimTime,
    /// End-to-end latency (arrival - depart).
    pub latency: f64,
    /// Number of hops traversed.
    pub hops: usize,
    /// Wire bytes put on the bottleneck edge.
    pub wire_bytes: u64,
    /// Queueing delay component (contention).
    pub queueing: f64,
}

impl Fabric {
    /// Build a fabric where every edge of `topo` uses the link spec chosen by
    /// `link_for` (edge index, endpoint kinds) — heterogeneous fabrics like
    /// CXL-over-XLink pick per-edge technologies here.
    pub fn new_with(topo: Topology, policy: RoutingPolicy, link_for: impl Fn(EdgeId, &Topology) -> LinkSpec) -> Self {
        let n = topo.edge_count();
        let links: Vec<LinkSpec> = (0..n).map(|e| link_for(e, &topo)).collect();
        Fabric {
            busy_until: vec![0.0; n],
            carried: vec![0; n],
            links,
            topo,
            policy,
            total_payload: 0,
            total_wire: 0,
            transfers: 0,
        }
    }

    /// Build a homogeneous fabric: every edge uses `link`.
    pub fn new(topo: Topology, link: LinkSpec, policy: RoutingPolicy) -> Self {
        Self::new_with(topo, policy, |_, _| link.clone())
    }

    /// The underlying topology.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Link spec of a directed edge.
    pub fn link(&self, e: EdgeId) -> &LinkSpec {
        &self.links[e]
    }

    /// Replace the link spec on one edge (heterogeneous fabric assembly).
    pub fn set_link(&mut self, e: EdgeId, spec: LinkSpec) {
        self.links[e] = spec;
    }

    /// Routing policy in force.
    pub fn policy(&self) -> RoutingPolicy {
        self.policy
    }

    /// Total payload bytes moved since construction.
    pub fn total_payload(&self) -> u64 {
        self.total_payload
    }

    /// Total wire bytes moved (payload × protocol framing expansion).
    pub fn total_wire(&self) -> u64 {
        self.total_wire
    }

    /// Number of transfers executed.
    pub fn transfers(&self) -> u64 {
        self.transfers
    }

    /// Payload bytes carried per edge.
    pub fn edge_carried(&self, e: EdgeId) -> u64 {
        self.carried[e]
    }

    /// Fail a directed edge (failure injection). Failed edges advertise
    /// infinite occupancy: PBR's congestion-aware choice routes around
    /// them, while HBR's fixed hierarchical path cannot (Table 1's
    /// resilience argument for port-based routing).
    pub fn fail_edge(&mut self, e: EdgeId) {
        self.busy_until[e] = f64::INFINITY;
    }

    /// Fail both directions of the link between two adjacent nodes.
    pub fn fail_link(&mut self, a: NodeId, b: NodeId) {
        for e in 0..self.topo.edge_count() {
            let (s, d) = self.topo.edge(e);
            if (s == a && d == b) || (s == b && d == a) {
                self.busy_until[e] = f64::INFINITY;
            }
        }
    }

    /// Repair a failed edge.
    pub fn repair_edge(&mut self, e: EdgeId) {
        if self.busy_until[e].is_infinite() {
            self.busy_until[e] = 0.0;
        }
    }

    /// Reset contention and accounting state (fresh experiment run).
    pub fn reset(&mut self) {
        for b in &mut self.busy_until {
            *b = 0.0;
        }
        for c in &mut self.carried {
            *c = 0;
        }
        self.total_payload = 0;
        self.total_wire = 0;
        self.transfers = 0;
    }

    /// Pure latency estimate for `bytes` from `src` to `dst` ignoring
    /// contention (used by placement heuristics and analytic models).
    pub fn latency_estimate(&self, src: NodeId, dst: NodeId, bytes: u64) -> Option<f64> {
        if src == dst {
            return Some(0.0);
        }
        let route = self.policy.route(&self.topo, src, dst, &self.busy_until)?;
        let mut lat = 0.0;
        let mut bottleneck: f64 = 0.0;
        for &e in route.edges() {
            let l = &self.links[e];
            lat += l.hop_latency();
            bottleneck = bottleneck.max(l.wire_time(bytes));
        }
        Some(lat + bottleneck)
    }

    /// Execute a transfer departing at `now`. Returns `None` when no route
    /// exists (disconnected topologies are an error the caller handles).
    pub fn transfer(&mut self, src: NodeId, dst: NodeId, bytes: u64, now: SimTime) -> Option<TransferResult> {
        if src == dst {
            return Some(TransferResult { arrival: now, latency: 0.0, hops: 0, wire_bytes: 0, queueing: 0.0 });
        }
        let route = self.policy.route(&self.topo, src, dst, &self.busy_until)?;
        let path = route.edges();
        // a route through a failed (infinite-occupancy) edge never delivers
        if path.iter().any(|&e| self.busy_until[e].is_infinite()) {
            return None;
        }
        let mut t = now;
        let mut queueing = 0.0;
        let mut bottleneck_wire_time: f64 = 0.0;
        let mut wire_bytes = 0u64;
        // Cut-through: the head of the message pays hop latency per hop and
        // waits for each edge to free; the body streams behind at the
        // bottleneck edge's rate.
        for &e in path {
            let l = &self.links[e];
            let free = self.busy_until[e];
            if free > t {
                queueing += free - t;
                t = free;
            }
            t += l.hop_latency();
            let wt = l.wire_time(bytes);
            // Edge is occupied while the body streams through it.
            self.busy_until[e] = t + wt;
            self.carried[e] += bytes;
            if wt > bottleneck_wire_time {
                bottleneck_wire_time = wt;
                wire_bytes = l.wire_bytes(bytes);
            }
        }
        let arrival = t + bottleneck_wire_time;
        self.total_payload += bytes;
        self.total_wire += wire_bytes;
        self.transfers += 1;
        Some(TransferResult { arrival, latency: arrival - now, hops: path.len(), wire_bytes, queueing })
    }

    /// Hop count between two nodes under the current policy (None if
    /// unreachable).
    pub fn hops(&self, src: NodeId, dst: NodeId) -> Option<usize> {
        if src == dst {
            return Some(0);
        }
        self.policy.route(&self.topo, src, dst, &self.busy_until).map(|p| p.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::topology::Topology;

    fn line_fabric(n: usize, link: LinkSpec) -> Fabric {
        let topo = Topology::line(n);
        Fabric::new(topo, link, RoutingPolicy::Hbr)
    }

    #[test]
    fn zero_byte_same_node() {
        let mut f = line_fabric(3, LinkSpec::cxl3_x16());
        let r = f.transfer(0, 0, 1024, 5.0).unwrap();
        assert_eq!(r.arrival, 5.0);
        assert_eq!(r.hops, 0);
    }

    #[test]
    fn latency_grows_with_hops() {
        let f1 = Fabric::new(Topology::switch_chain(1), LinkSpec::cxl3_x16(), RoutingPolicy::Hbr);
        let f3 = Fabric::new(Topology::switch_chain(5), LinkSpec::cxl3_x16(), RoutingPolicy::Hbr);
        let a = f1.latency_estimate(0, 1, 64).unwrap();
        let b = f3.latency_estimate(0, 1, 64).unwrap();
        assert!(b > a * 2.0, "a={a} b={b}");
    }

    #[test]
    fn big_messages_pay_wire_time() {
        let f = line_fabric(2, LinkSpec::cxl3_x16());
        let small = f.latency_estimate(0, 1, 64).unwrap();
        let big = f.latency_estimate(0, 1, 64 * 1024 * 1024).unwrap();
        // 64 MiB at 128 GB/s ~ 0.5 ms >> port latency
        assert!(big > small * 100.0);
    }

    #[test]
    fn contention_queues_second_transfer() {
        let mut f = line_fabric(2, LinkSpec::cxl3_x16());
        let r1 = f.transfer(0, 1, 10_000_000, 0.0).unwrap();
        let r2 = f.transfer(0, 1, 10_000_000, 0.0).unwrap();
        assert!(r2.queueing > 0.0);
        assert!(r2.arrival > r1.arrival);
    }

    #[test]
    fn accounting_tracks_payload_and_wire() {
        let mut f = line_fabric(2, LinkSpec::ualink1_x4());
        f.transfer(0, 1, 1000, 0.0).unwrap();
        assert_eq!(f.total_payload(), 1000);
        assert!(f.total_wire() >= 1000, "framing should not shrink bytes");
        assert_eq!(f.transfers(), 1);
    }

    #[test]
    fn pbr_routes_around_failed_plane_hbr_cannot() {
        // Table 1 resilience: PBR reroutes, HBR's fixed path dies.
        let mk = |policy| Fabric::new(Topology::single_clos(4, 2), LinkSpec::cxl3_x16(), policy);
        let mut hbr = mk(RoutingPolicy::Hbr);
        let mut pbr = mk(RoutingPolicy::Pbr);
        let eps = hbr.topology().endpoints().to_vec();
        // find HBR's plane and fail it on both fabrics
        let busy = vec![0.0; hbr.topology().edge_count()];
        let hbr_path = RoutingPolicy::Hbr.route(hbr.topology(), eps[0], eps[1], &busy).unwrap().to_vec();
        for &e in &hbr_path {
            hbr.fail_edge(e);
            pbr.fail_edge(e);
        }
        assert!(hbr.transfer(eps[0], eps[1], 64, 0.0).is_none(), "HBR must lose the path");
        let r = pbr.transfer(eps[0], eps[1], 64, 0.0);
        assert!(r.is_some(), "PBR must reroute via the surviving plane");
    }

    #[test]
    fn repair_restores_hbr_path() {
        let mut f = Fabric::new(Topology::star(4), LinkSpec::cxl3_x16(), RoutingPolicy::Hbr);
        let eps = f.topology().endpoints().to_vec();
        let busy = vec![0.0; f.topology().edge_count()];
        let path = RoutingPolicy::Hbr.route(f.topology(), eps[0], eps[1], &busy).unwrap().to_vec();
        f.fail_edge(path[0]);
        assert!(f.transfer(eps[0], eps[1], 64, 0.0).is_none());
        f.repair_edge(path[0]);
        assert!(f.transfer(eps[0], eps[1], 64, 0.0).is_some());
    }

    #[test]
    fn reset_clears_state() {
        let mut f = line_fabric(2, LinkSpec::cxl3_x16());
        f.transfer(0, 1, 1 << 20, 0.0).unwrap();
        f.reset();
        assert_eq!(f.total_payload(), 0);
        let r = f.transfer(0, 1, 64, 0.0).unwrap();
        assert_eq!(r.queueing, 0.0);
    }
}
