//! Flit / packet framing models (Table 3 and §6.1).
//!
//! Each interconnect moves payload in protocol-specific units:
//!
//! * **CXL HBR**: 68-byte flits carrying 64 B of payload (CXL 1.0–2.0, and
//!   3.0 in HBR mode at up to 32 GT/s).
//! * **CXL PBR**: 256-byte flits (CXL 3.0 at 64 GT/s); ~16 B of
//!   header/CRC/credit leaves ~240 B payload.
//! * **NVLink 5.0**: packets of one 16 B header flit plus 2–16 data flits of
//!   16 B, i.e. 48–272 B total carrying 32–256 B payload (§6.1 footnote).
//! * **UALink 1.0**: 640-byte data-link flits optimized for bulk transfers;
//!   we model 608 B payload per flit (~5% framing).
//! * **Ethernet / InfiniBand**: MTU-sized frames with fixed header overhead.
//!
//! `wire_bytes(payload)` is the number of bytes actually serialized on the
//! link; `efficiency()` is payload/wire for large messages.

/// A framing format: fixed-size unit with a payload capacity, or MTU frames.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FlitFormat {
    /// Total unit size on the wire in bytes.
    pub unit: u64,
    /// Payload bytes carried per unit.
    pub payload: u64,
    /// Minimum wire bytes for any message (header-only cost).
    pub min_wire: u64,
}

impl FlitFormat {
    /// CXL 68-byte flit (HBR mode; CXL 1.0/2.0/3.0-HBR).
    pub const CXL_68B: FlitFormat = FlitFormat { unit: 68, payload: 64, min_wire: 68 };
    /// CXL 256-byte flit (PBR mode; CXL 3.0): 2 B header + CRC/DLP fields
    /// leave ~244 B of slot payload — better amortization than HBR's 64/68.
    pub const CXL_256B: FlitFormat = FlitFormat { unit: 256, payload: 244, min_wire: 256 };
    /// NVLink 5.0 packet: 16B header + up to 16×16B data flits. We model the
    /// steady-state max-size packet (272 B carrying 256 B).
    pub const NVLINK_PACKET: FlitFormat = FlitFormat { unit: 272, payload: 256, min_wire: 48 };
    /// UALink 1.0 640-byte flit.
    pub const UALINK_640B: FlitFormat = FlitFormat { unit: 640, payload: 608, min_wire: 640 };
    /// Ethernet jumbo frame (RoCEv2): 9000 B MTU, ~96 B headers (Eth+IP+UDP+
    /// IB BTH+ICRC+FCS+preamble/IFG equivalent).
    pub const ETHERNET_JUMBO: FlitFormat = FlitFormat { unit: 9096, payload: 9000, min_wire: 160 };
    /// InfiniBand 4096 B MTU, ~58 B of LRH/GRH/BTH/CRC framing.
    pub const INFINIBAND_4K: FlitFormat = FlitFormat { unit: 4154, payload: 4096, min_wire: 78 };
    /// PCIe TLP: 256 B max payload with ~24 B TLP/DLLP/framing overhead.
    pub const PCIE_TLP: FlitFormat = FlitFormat { unit: 280, payload: 256, min_wire: 44 };
    /// Idealized lossless framing (for sensitivity baselines).
    pub const IDEAL: FlitFormat = FlitFormat { unit: 1, payload: 1, min_wire: 0 };

    /// Bytes serialized on the wire for a `payload_bytes` message.
    pub fn wire_bytes(&self, payload_bytes: u64) -> u64 {
        if payload_bytes == 0 {
            return self.min_wire;
        }
        let units = payload_bytes.div_ceil(self.payload);
        (units * self.unit).max(self.min_wire)
    }

    /// Asymptotic payload efficiency (payload / wire) for large messages.
    pub fn efficiency(&self) -> f64 {
        self.payload as f64 / self.unit as f64
    }

    /// Framing expansion factor (wire / payload) for large messages.
    pub fn expansion(&self) -> f64 {
        self.unit as f64 / self.payload as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cxl_hbr_efficiency() {
        let f = FlitFormat::CXL_68B;
        assert!((f.efficiency() - 64.0 / 68.0).abs() < 1e-12);
        assert_eq!(f.wire_bytes(64), 68);
        assert_eq!(f.wire_bytes(65), 136);
    }

    #[test]
    fn cxl_pbr_less_overhead_for_bulk() {
        // PBR's 256B flit amortizes header better than HBR's 68B flit.
        assert!(FlitFormat::CXL_256B.efficiency() > FlitFormat::CXL_68B.efficiency());
    }

    #[test]
    fn nvlink_small_packet_floor() {
        let f = FlitFormat::NVLINK_PACKET;
        // a 4-byte message still costs a min packet (header+2 data flits)
        assert_eq!(f.wire_bytes(4), 272.max(48));
    }

    #[test]
    fn ualink_bulk_oriented() {
        // UALink pays more than CXL-PBR on tiny messages but is efficient in bulk.
        let tiny_ua = FlitFormat::UALINK_640B.wire_bytes(32);
        let tiny_cxl = FlitFormat::CXL_256B.wire_bytes(32);
        assert!(tiny_ua > tiny_cxl);
        assert!(FlitFormat::UALINK_640B.efficiency() > 0.93);
    }

    #[test]
    fn wire_bytes_monotone_nondecreasing() {
        for f in [
            FlitFormat::CXL_68B,
            FlitFormat::CXL_256B,
            FlitFormat::NVLINK_PACKET,
            FlitFormat::UALINK_640B,
            FlitFormat::ETHERNET_JUMBO,
            FlitFormat::INFINIBAND_4K,
            FlitFormat::PCIE_TLP,
        ] {
            let mut prev = 0;
            for b in [0u64, 1, 63, 64, 65, 255, 256, 1024, 1 << 20] {
                let w = f.wire_bytes(b);
                assert!(w >= prev, "{f:?} non-monotone at {b}");
                assert!(w >= b, "{f:?} wire < payload at {b}");
                prev = w;
            }
        }
    }

    #[test]
    fn zero_payload_costs_header() {
        assert_eq!(FlitFormat::ETHERNET_JUMBO.wire_bytes(0), 160);
    }
}
