//! Routing policies: HBR vs PBR (Table 1, §4.2).
//!
//! * **HBR (hierarchical-based routing)** — CXL 2.0 semantics: one fixed
//!   deterministic shortest path per (src, dst) pair; no load awareness.
//! * **PBR (port-based routing)** — CXL 3.0 semantics: pick among
//!   equal-cost shortest paths based on real-time port congestion, enabling
//!   traffic spreading and genuine multi-path fabrics.

use super::topology::{NodeId, Topology};
use crate::sim::SimTime;
use std::sync::Arc;

/// A selected route: shared ownership of cached path storage — zero path
/// copies on the hot transfer path (§Perf). `Arc`-backed so routes can be
/// carried across threads along with their (now `Sync`) topology.
#[derive(Clone, Debug)]
pub enum Route {
    /// The single cached shortest path (HBR).
    Single(Arc<Vec<usize>>),
    /// Index into a cached equal-cost candidate set (PBR).
    OneOf(Arc<Vec<Vec<usize>>>, usize),
}

impl Route {
    /// Edge ids along the path.
    pub fn edges(&self) -> &[usize] {
        match self {
            Route::Single(p) => p,
            Route::OneOf(set, i) => &set[*i],
        }
    }

    /// Hop count.
    pub fn len(&self) -> usize {
        self.edges().len()
    }

    /// Zero-hop route?
    pub fn is_empty(&self) -> bool {
        self.edges().is_empty()
    }

    /// Materialize the edge list (tests / diagnostics).
    pub fn to_vec(&self) -> Vec<usize> {
        self.edges().to_vec()
    }
}

/// Path-selection policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Fixed hierarchical path (CXL 2.0 / conventional up-down routing).
    Hbr,
    /// Congestion-aware equal-cost multipath (CXL 3.0).
    Pbr,
}

impl RoutingPolicy {
    /// Maximum equal-cost alternatives PBR considers.
    const PBR_FANOUT: usize = 8;

    /// Choose a path from `src` to `dst`. `busy_until` holds per-edge
    /// occupancy (indexed by edge id) that PBR uses for load-aware choice.
    pub fn route(&self, topo: &Topology, src: NodeId, dst: NodeId, busy_until: &[SimTime]) -> Option<Route> {
        match self {
            RoutingPolicy::Hbr => topo.shortest_path(src, dst).map(Route::Single),
            RoutingPolicy::Pbr => {
                let candidates = topo.equal_cost_paths_cached(src, dst, Self::PBR_FANOUT);
                if candidates.is_empty() {
                    return None;
                }
                // least-congested: minimize the max busy_until along the path
                let mut best = 0usize;
                let mut best_load = f64::INFINITY;
                for (i, path) in candidates.iter().enumerate() {
                    let load = path.iter().map(|&e| busy_until[e]).fold(0.0f64, f64::max);
                    if load < best_load {
                        best_load = load;
                        best = i;
                    }
                }
                Some(Route::OneOf(candidates, best))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::topology::Topology;

    #[test]
    fn hbr_is_deterministic() {
        let t = Topology::single_clos(8, 4);
        let eps = t.endpoints().to_vec();
        let busy = vec![0.0; t.edge_count()];
        let a = RoutingPolicy::Hbr.route(&t, eps[0], eps[3], &busy).unwrap();
        let b = RoutingPolicy::Hbr.route(&t, eps[0], eps[3], &busy).unwrap();
        assert_eq!(a.to_vec(), b.to_vec());
    }

    #[test]
    fn pbr_avoids_congested_plane() {
        let t = Topology::single_clos(4, 2);
        let eps = t.endpoints().to_vec();
        let mut busy = vec![0.0; t.edge_count()];
        // Find HBR's preferred path and congest it heavily.
        let hbr_path = RoutingPolicy::Hbr.route(&t, eps[0], eps[1], &busy).unwrap().to_vec();
        for &e in &hbr_path {
            busy[e] = 1e9;
        }
        let pbr_path = RoutingPolicy::Pbr.route(&t, eps[0], eps[1], &busy).unwrap();
        assert_ne!(pbr_path.to_vec(), hbr_path, "PBR should divert to the idle plane");
        let load = pbr_path.edges().iter().map(|&e| busy[e]).fold(0.0f64, f64::max);
        assert_eq!(load, 0.0);
    }

    #[test]
    fn pbr_equals_hbr_length() {
        // PBR only picks among *equal-cost* paths — no path inflation.
        let t = Topology::multi_clos(32, 8, 4);
        let eps = t.endpoints().to_vec();
        let busy = vec![0.0; t.edge_count()];
        let h = RoutingPolicy::Hbr.route(&t, eps[0], eps[31], &busy).unwrap();
        let p = RoutingPolicy::Pbr.route(&t, eps[0], eps[31], &busy).unwrap();
        assert_eq!(h.len(), p.len());
    }

    #[test]
    fn unreachable_is_none() {
        let mut t = Topology::empty(crate::fabric::topology::TopologyKind::Custom);
        let a = t.add_node(crate::fabric::topology::NodeKind::Endpoint);
        let b = t.add_node(crate::fabric::topology::NodeKind::Endpoint);
        let busy: Vec<f64> = Vec::new();
        assert!(RoutingPolicy::Hbr.route(&t, a, b, &busy).is_none());
        assert!(RoutingPolicy::Pbr.route(&t, a, b, &busy).is_none());
    }
}
