//! Indexed binary min-heap over `(time, id)` pairs.
//!
//! [`FinishHeap`] tracks the predicted completion time of every active flow
//! in [`super::flow::FabricSim`] so the next completion is an O(1) peek and
//! a rate repair touching `k` flows costs `O(k log n)` heap updates —
//! replacing the `O(active)` linear `next_finish` scan that made every
//! event pay for the whole population. Ordering is `(time, id)`: equal
//! times pop in ascending flow-id order, which keeps the engine's
//! deterministic-trace contract independent of insertion history.

use crate::sim::SimTime;
use std::collections::HashMap;

/// Indexed min-heap of `(finish time, flow id)` with O(log n) upsert and
/// remove by id. Times may be `f64::INFINITY` (stalled flows park at the
/// back); `NaN` is rejected in debug builds.
#[derive(Default)]
pub struct FinishHeap {
    heap: Vec<(SimTime, u64)>,
    /// id -> current index in `heap`.
    // detlint: allow(hash-order) -- hot-path bookkeeping, get/insert/remove by id only; ordering authority is the heap array itself
    pos: HashMap<u64, usize>,
}

impl FinishHeap {
    /// Empty heap.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of tracked ids.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether the heap is empty.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Whether `id` is tracked.
    pub fn contains(&self, id: u64) -> bool {
        self.pos.contains_key(&id)
    }

    /// Earliest `(time, id)` without removing it.
    pub fn peek(&self) -> Option<(SimTime, u64)> {
        self.heap.first().copied()
    }

    /// Remove and return the earliest `(time, id)`.
    pub fn pop(&mut self) -> Option<(SimTime, u64)> {
        if self.heap.is_empty() {
            return None;
        }
        let top = self.heap.swap_remove(0);
        self.pos.remove(&top.1);
        if !self.heap.is_empty() {
            self.pos.insert(self.heap[0].1, 0);
            self.sift_down(0);
        }
        Some(top)
    }

    /// Insert `id` at `t`, or reschedule it if already tracked.
    pub fn upsert(&mut self, id: u64, t: SimTime) {
        debug_assert!(!t.is_nan(), "finish time must not be NaN");
        match self.pos.get(&id).copied() {
            Some(i) => {
                self.heap[i].0 = t;
                if self.sift_up(i) == i {
                    self.sift_down(i);
                }
            }
            None => {
                let i = self.heap.len();
                self.heap.push((t, id));
                self.pos.insert(id, i);
                self.sift_up(i);
            }
        }
    }

    /// Remove `id` if tracked; returns whether it was.
    pub fn remove(&mut self, id: u64) -> bool {
        let Some(i) = self.pos.remove(&id) else { return false };
        if i == self.heap.len() - 1 {
            self.heap.pop();
            return true;
        }
        self.heap.swap_remove(i);
        self.pos.insert(self.heap[i].1, i);
        if self.sift_up(i) == i {
            self.sift_down(i);
        }
        true
    }

    fn less(a: (SimTime, u64), b: (SimTime, u64)) -> bool {
        a.0 < b.0 || (a.0 == b.0 && a.1 < b.1)
    }

    /// Bubble `i` up; returns the final index.
    fn sift_up(&mut self, mut i: usize) -> usize {
        while i > 0 {
            let p = (i - 1) / 2;
            if Self::less(self.heap[i], self.heap[p]) {
                self.swap(i, p);
                i = p;
            } else {
                break;
            }
        }
        i
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut m = i;
            if l < self.heap.len() && Self::less(self.heap[l], self.heap[m]) {
                m = l;
            }
            if r < self.heap.len() && Self::less(self.heap[r], self.heap[m]) {
                m = r;
            }
            if m == i {
                break;
            }
            self.swap(i, m);
            i = m;
        }
    }

    fn swap(&mut self, a: usize, b: usize) {
        self.heap.swap(a, b);
        self.pos.insert(self.heap[a].1, a);
        self.pos.insert(self.heap[b].1, b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut h = FinishHeap::new();
        for (id, t) in [(0u64, 30.0), (1, 10.0), (2, 20.0), (3, 5.0)] {
            h.upsert(id, t);
        }
        assert_eq!(h.len(), 4);
        assert_eq!(h.peek(), Some((5.0, 3)));
        let order: Vec<u64> = std::iter::from_fn(|| h.pop()).map(|(_, id)| id).collect();
        assert_eq!(order, vec![3, 1, 2, 0]);
        assert!(h.is_empty());
    }

    #[test]
    fn ties_pop_in_id_order() {
        let mut h = FinishHeap::new();
        for id in [7u64, 2, 9, 4] {
            h.upsert(id, 1.0);
        }
        let order: Vec<u64> = std::iter::from_fn(|| h.pop()).map(|(_, id)| id).collect();
        assert_eq!(order, vec![2, 4, 7, 9]);
    }

    #[test]
    fn upsert_reschedules_both_directions() {
        let mut h = FinishHeap::new();
        h.upsert(1, 10.0);
        h.upsert(2, 20.0);
        h.upsert(3, 30.0);
        h.upsert(3, 1.0); // move earlier
        assert_eq!(h.peek(), Some((1.0, 3)));
        h.upsert(3, 99.0); // move later
        assert_eq!(h.peek(), Some((10.0, 1)));
        assert_eq!(h.len(), 3);
    }

    #[test]
    fn remove_middle_keeps_order() {
        let mut h = FinishHeap::new();
        for (id, t) in [(1u64, 10.0), (2, 20.0), (3, 30.0), (4, 40.0)] {
            h.upsert(id, t);
        }
        assert!(h.remove(2));
        assert!(!h.remove(2));
        assert!(!h.contains(2));
        let order: Vec<u64> = std::iter::from_fn(|| h.pop()).map(|(_, id)| id).collect();
        assert_eq!(order, vec![1, 3, 4]);
    }

    #[test]
    fn infinite_times_park_at_the_back() {
        let mut h = FinishHeap::new();
        h.upsert(1, f64::INFINITY);
        h.upsert(2, 5.0);
        h.upsert(3, f64::INFINITY);
        assert_eq!(h.pop(), Some((5.0, 2)));
        // the two stalled entries tie on time and pop by id
        assert_eq!(h.pop().map(|(_, id)| id), Some(1));
        assert_eq!(h.pop().map(|(_, id)| id), Some(3));
    }

    #[test]
    fn fuzz_against_reference_sort() {
        let mut rng = crate::sim::Rng::new(42);
        let mut h = FinishHeap::new();
        let mut reference: std::collections::BTreeMap<u64, u64> = std::collections::BTreeMap::new();
        for step in 0..2000u64 {
            match rng.index(4) {
                0 | 1 => {
                    let id = step;
                    let t = rng.below(1000);
                    h.upsert(id, t as f64);
                    reference.insert(id, t);
                }
                2 => {
                    if let Some((&id, _)) = reference.iter().next() {
                        let t = rng.below(1000);
                        h.upsert(id, t as f64);
                        reference.insert(id, t);
                    }
                }
                _ => {
                    if let Some((&id, _)) = reference.iter().next_back() {
                        reference.remove(&id);
                        assert!(h.remove(id));
                    }
                }
            }
            assert_eq!(h.len(), reference.len());
        }
        // drain: must match the reference sorted by (time, id)
        let mut expect: Vec<(u64, u64)> = reference.iter().map(|(&id, &t)| (t, id)).collect();
        expect.sort_unstable();
        let got: Vec<(u64, u64)> = std::iter::from_fn(|| h.pop()).map(|(t, id)| (t as u64, id)).collect();
        assert_eq!(got, expect);
    }
}
