//! CXL specification capability matrix (Table 1, §4.2).
//!
//! Encodes what each CXL generation can do — the feature deltas that drive
//! the composability story: controller decoupling (1.0), single-level
//! switching + pooling + hot-plug (2.0), multi-level cascades + PBR +
//! genuine multi-host sharing + back-invalidation + P2P (3.0).

use super::flit::FlitFormat;

/// CXL specification generation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum CxlVersion {
    /// CXL 1.0/1.1 — direct endpoint attach only.
    V1_0,
    /// CXL 2.0 — single-level switching, pooling, hot-plug, HBR.
    V2_0,
    /// CXL 3.x — multi-level cascades, PBR, sharing, back-invalidation, P2P.
    V3_0,
}

impl CxlVersion {
    /// Max link rate in GT/s (Table 1).
    pub fn max_link_rate_gts(self) -> u32 {
        match self {
            CxlVersion::V1_0 | CxlVersion::V2_0 => 32,
            CxlVersion::V3_0 => 64,
        }
    }

    /// Flit formats supported.
    pub fn flit_formats(self) -> &'static [FlitFormat] {
        match self {
            CxlVersion::V1_0 | CxlVersion::V2_0 => &[FlitFormat::CXL_68B],
            CxlVersion::V3_0 => &[FlitFormat::CXL_68B, FlitFormat::CXL_256B],
        }
    }

    /// Memory-controller decoupling (all versions — the founding feature).
    pub fn controller_decoupling(self) -> bool {
        true
    }

    /// Memory expansion beyond the CPU package.
    pub fn memory_expansion(self) -> bool {
        true
    }

    /// Memory pooling across hosts (2.0+, static partitioning).
    pub fn memory_pooling(self) -> bool {
        self >= CxlVersion::V2_0
    }

    /// Genuine multi-host coherent memory *sharing* (3.0).
    pub fn memory_sharing(self) -> bool {
        self >= CxlVersion::V3_0
    }

    /// Any switching at all (2.0+).
    pub fn switching(self) -> bool {
        self >= CxlVersion::V2_0
    }

    /// Multi-level switch cascading (3.0).
    pub fn multi_level_switching(self) -> bool {
        self >= CxlVersion::V3_0
    }

    /// Hierarchical-based routing (2.0+).
    pub fn hbr(self) -> bool {
        self >= CxlVersion::V2_0
    }

    /// Port-based routing (3.0).
    pub fn pbr(self) -> bool {
        self >= CxlVersion::V3_0
    }

    /// Hot-plug of endpoints (2.0+).
    pub fn hot_plug(self) -> bool {
        self >= CxlVersion::V2_0
    }

    /// Back-invalidation coherence (3.0).
    pub fn back_invalidation(self) -> bool {
        self >= CxlVersion::V3_0
    }

    /// Direct peer-to-peer device communication (3.0).
    pub fn peer_to_peer(self) -> bool {
        self >= CxlVersion::V3_0
    }

    /// Max accelerators (Type 1/2 devices) per root port (Table 1).
    pub fn max_accelerators_per_port(self) -> usize {
        match self {
            CxlVersion::V1_0 | CxlVersion::V2_0 => 1,
            CxlVersion::V3_0 => 256,
        }
    }

    /// Max memory (Type 3) devices per root port (Table 1).
    pub fn max_memory_devices_per_port(self) -> usize {
        match self {
            CxlVersion::V1_0 => 1,
            CxlVersion::V2_0 => 256,
            CxlVersion::V3_0 => 4096,
        }
    }

    /// Practical memory-expander count per port for 2.0 deployments (§4.2:
    /// "4 to 16 in practice, well below the theoretical 256").
    pub fn practical_memory_devices_per_port(self) -> usize {
        match self {
            CxlVersion::V1_0 => 1,
            CxlVersion::V2_0 => 16,
            CxlVersion::V3_0 => 4096,
        }
    }

    /// Release year (Table 1).
    pub fn release_year(self) -> u32 {
        match self {
            CxlVersion::V1_0 => 2019,
            CxlVersion::V2_0 => 2020,
            CxlVersion::V3_0 => 2022,
        }
    }

    /// All versions, oldest first.
    pub fn all() -> [CxlVersion; 3] {
        [CxlVersion::V1_0, CxlVersion::V2_0, CxlVersion::V3_0]
    }
}

/// CXL sub-protocols (§6.2/§6.3 lightweight-implementation options).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CxlProtocol {
    /// Cache-coherence traffic (CXL.cache).
    Cache,
    /// Load/store memory access (CXL.mem).
    Mem,
    /// Bulk I/O semantics (CXL.io).
    Io,
}

/// A (possibly trimmed) protocol stack on a CXL device or switch — §6.3's
/// lightweight implementations disable sub-protocols to cut cost/latency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CxlStack {
    pub cache: bool,
    pub mem: bool,
    pub io: bool,
}

impl CxlStack {
    /// Full CXL stack.
    pub fn full() -> Self {
        CxlStack { cache: true, mem: true, io: true }
    }

    /// Coherence-centric lightweight stack (tier-1, §6.3).
    pub fn coherence_centric() -> Self {
        CxlStack { cache: true, mem: false, io: false }
    }

    /// Capacity-oriented stack (tier-2 pools, §6.3): CXL.mem only.
    pub fn capacity_oriented() -> Self {
        CxlStack { cache: false, mem: true, io: false }
    }

    /// Bulk-staging stack (tier-2 as storage-like, §6.3): CXL.io only.
    pub fn io_only() -> Self {
        CxlStack { cache: false, mem: false, io: true }
    }

    /// Supports coherent load/store sharing?
    pub fn coherent_sharing(&self) -> bool {
        self.cache
    }

    /// Supports direct load/store at all?
    pub fn load_store(&self) -> bool {
        self.mem || self.cache
    }

    /// Relative controller complexity (1.0 = full stack); trimmed stacks are
    /// cheaper — the §6.3 cost argument.
    pub fn complexity(&self) -> f64 {
        let mut c = 0.2; // PHY + link baseline
        if self.cache {
            c += 0.4;
        }
        if self.mem {
            c += 0.25;
        }
        if self.io {
            c += 0.15;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_feature_matrix() {
        use CxlVersion::*;
        assert!(!V1_0.memory_pooling() && V2_0.memory_pooling() && V3_0.memory_pooling());
        assert!(!V1_0.memory_sharing() && !V2_0.memory_sharing() && V3_0.memory_sharing());
        assert!(!V1_0.switching() && V2_0.switching());
        assert!(!V2_0.multi_level_switching() && V3_0.multi_level_switching());
        assert!(!V2_0.pbr() && V3_0.pbr());
        assert!(!V1_0.hot_plug() && V2_0.hot_plug());
        assert!(!V2_0.back_invalidation() && V3_0.back_invalidation());
        assert!(!V2_0.peer_to_peer() && V3_0.peer_to_peer());
    }

    #[test]
    fn table1_device_counts() {
        use CxlVersion::*;
        assert_eq!(V1_0.max_memory_devices_per_port(), 1);
        assert_eq!(V2_0.max_memory_devices_per_port(), 256);
        assert_eq!(V3_0.max_memory_devices_per_port(), 4096);
        assert_eq!(V2_0.max_accelerators_per_port(), 1);
        assert_eq!(V3_0.max_accelerators_per_port(), 256);
    }

    #[test]
    fn table1_link_rates() {
        assert_eq!(CxlVersion::V2_0.max_link_rate_gts(), 32);
        assert_eq!(CxlVersion::V3_0.max_link_rate_gts(), 64);
        assert_eq!(CxlVersion::V3_0.flit_formats().len(), 2);
    }

    #[test]
    fn lightweight_stacks_cheaper() {
        let full = CxlStack::full().complexity();
        assert!(CxlStack::coherence_centric().complexity() < full);
        assert!(CxlStack::capacity_oriented().complexity() < full);
        assert!(CxlStack::io_only().complexity() < CxlStack::capacity_oriented().complexity());
    }

    #[test]
    fn trimmed_stack_semantics() {
        assert!(CxlStack::coherence_centric().coherent_sharing());
        assert!(!CxlStack::capacity_oriented().coherent_sharing());
        assert!(CxlStack::capacity_oriented().load_store());
        assert!(!CxlStack::io_only().load_store());
    }
}
