//! Switch models: radix, latency, cost (Fig 29 trade-offs, §4.3 MoR/ToR).

use super::link::LinkClass;

/// One switch ASIC / tray model.
#[derive(Clone, Debug, PartialEq)]
pub struct SwitchSpec {
    pub name: &'static str,
    /// Link technology on its ports.
    pub class: LinkClass,
    /// Number of ports.
    pub radix: usize,
    /// Per-port unidirectional bandwidth (bytes/ns == GB/s).
    pub port_bw: f64,
    /// Cut-through forwarding latency (ns).
    pub latency: f64,
    /// Relative cost unit (for Fig 29's cost axis; 1.0 = one CXL switch).
    pub cost_units: f64,
    /// Power draw (W), for TCO-style reporting.
    pub power_w: f64,
}

impl SwitchSpec {
    /// CXL 3.x PBR fabric switch (Table 1: multi-level cascade capable).
    pub fn cxl3_switch() -> SwitchSpec {
        SwitchSpec { name: "CXL3-switch", class: LinkClass::Cxl3, radix: 64, port_bw: 128.0, latency: 60.0, cost_units: 1.0, power_w: 150.0 }
    }

    /// CXL 2.0 switch (single-level only).
    pub fn cxl2_switch() -> SwitchSpec {
        SwitchSpec { name: "CXL2-switch", class: LinkClass::Cxl2, radix: 32, port_bw: 64.0, latency: 70.0, cost_units: 0.8, power_w: 120.0 }
    }

    /// NVSwitch generation 4 (NVL72 class).
    pub fn nvswitch() -> SwitchSpec {
        SwitchSpec { name: "NVSwitch4", class: LinkClass::NvLink, radix: 72, port_bw: 100.0, latency: 100.0, cost_units: 2.5, power_w: 300.0 }
    }

    /// UALink 1.0 switch.
    pub fn ualink_switch() -> SwitchSpec {
        SwitchSpec { name: "UALink-switch", class: LinkClass::UaLink, radix: 128, port_bw: 100.0, latency: 150.0, cost_units: 1.5, power_w: 200.0 }
    }

    /// Ethernet ToR/aggregation switch (Spectrum-X class).
    pub fn ethernet_tor() -> SwitchSpec {
        SwitchSpec { name: "Eth-ToR-800G", class: LinkClass::Ethernet, radix: 64, port_bw: 100.0, latency: 600.0, cost_units: 1.2, power_w: 350.0 }
    }

    /// InfiniBand Quantum-2 class switch.
    pub fn infiniband_switch() -> SwitchSpec {
        SwitchSpec { name: "IB-Quantum2", class: LinkClass::InfiniBand, radix: 64, port_bw: 50.0, latency: 130.0, cost_units: 1.8, power_w: 320.0 }
    }

    /// Aggregate switching bandwidth (bytes/ns).
    pub fn aggregate_bw(&self) -> f64 {
        self.radix as f64 * self.port_bw
    }
}

/// Number of switches a topology shape needs for `n` endpoints (Fig 29's
/// cost-growth comparison). Analytic counts, matching the builders in
/// [`super::topology`].
pub fn switches_required(kind: crate::fabric::topology::TopologyKind, n: usize, radix: usize) -> usize {
    use crate::fabric::topology::TopologyKind::*;
    match kind {
        FullyConnected => 0,
        Line | Custom => 0,
        Star => 1,
        SingleClos => {
            // planes needed so that aggregate plane ports >= n endpoints,
            // NVSwitch style: each endpoint takes one port on every plane, so
            // a single-hop Clos works only while n <= radix; beyond that it
            // cannot scale (the paper's rack-level scale-up ceiling).
            if n <= radix {
                1
            } else {
                usize::MAX // not constructible: scale-up ceiling
            }
        }
        MultiClos => {
            // leaves with radix/2 down-ports + radix/2 up-ports, plus spines.
            let down = (radix / 2).max(1);
            let leaves = n.div_ceil(down);
            let spines = leaves.div_ceil(2).max(1);
            leaves + spines
        }
        Torus3D => n,     // router integrated per node
        DragonFly => n,   // router per node (one endpoint per router here)
        SpineLeaf => {
            let down = (radix / 2).max(1);
            let tors = n.div_ceil(down);
            let spines = tors.div_ceil(4).max(1);
            tors + spines
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::topology::TopologyKind;

    #[test]
    fn aggregate_bandwidth() {
        let s = SwitchSpec::cxl3_switch();
        assert_eq!(s.aggregate_bw(), 64.0 * 128.0);
    }

    #[test]
    fn single_clos_scale_ceiling() {
        // The paper: NVLink/UALink single-hop Clos is confined to rack scale.
        assert_eq!(switches_required(TopologyKind::SingleClos, 64, 72), 1);
        assert_eq!(switches_required(TopologyKind::SingleClos, 1024, 72), usize::MAX);
    }

    #[test]
    fn multi_clos_grows_sublinearly() {
        let a = switches_required(TopologyKind::MultiClos, 256, 64);
        let b = switches_required(TopologyKind::MultiClos, 1024, 64);
        assert!(b < a * 8, "a={a} b={b}");
        assert!(b > a);
    }

    #[test]
    fn direct_networks_embed_routers() {
        assert_eq!(switches_required(TopologyKind::Torus3D, 512, 64), 512);
        assert_eq!(switches_required(TopologyKind::DragonFly, 512, 64), 512);
    }

    #[test]
    fn cxl_switch_fastest_fabric_switch() {
        assert!(SwitchSpec::cxl3_switch().latency < SwitchSpec::nvswitch().latency);
        assert!(SwitchSpec::nvswitch().latency < SwitchSpec::ethernet_tor().latency);
    }
}
