//! Event tracing: lightweight structured records for debugging experiments
//! and for the data-movement accounting the paper reports (e.g. the 21.1×
//! data-movement reduction in Fig 31 is a traffic *accounting* number).

use super::SimTime;

/// Categories of traced activity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TraceKind {
    /// Message injected into a fabric.
    Send,
    /// Message delivered.
    Deliver,
    /// Compute phase executed.
    Compute,
    /// Memory access serviced.
    MemAccess,
    /// Coherence action (invalidate, back-invalidate, writeback).
    Coherence,
    /// Coordinator decision (routing, batching, placement).
    Decision,
}

/// One trace record.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub time: SimTime,
    pub kind: TraceKind,
    /// Free-form tag, e.g. "allreduce", "kv_fetch".
    pub tag: &'static str,
    /// Bytes moved (0 for non-transfer events).
    pub bytes: u64,
    /// Duration of the activity in ns.
    pub dur: f64,
}

/// Bounded in-memory trace with aggregate accounting.
#[derive(Debug)]
pub struct Trace {
    events: Vec<TraceEvent>,
    cap: usize,
    /// Total bytes per kind even when events are dropped beyond `cap`.
    bytes_sent: u64,
    bytes_mem: u64,
    coherence_ops: u64,
    enabled: bool,
}

impl Default for Trace {
    fn default() -> Self {
        Self::new(1 << 16)
    }
}

impl Trace {
    /// Trace retaining up to `cap` full records (aggregates are unbounded).
    pub fn new(cap: usize) -> Self {
        Trace { events: Vec::new(), cap, bytes_sent: 0, bytes_mem: 0, coherence_ops: 0, enabled: true }
    }

    /// Disable record retention (aggregates still update). Hot-path friendly.
    pub fn aggregates_only() -> Self {
        let mut t = Self::new(0);
        t.enabled = false;
        t
    }

    /// Record an event.
    pub fn record(&mut self, ev: TraceEvent) {
        match ev.kind {
            TraceKind::Send => self.bytes_sent += ev.bytes,
            TraceKind::MemAccess => self.bytes_mem += ev.bytes,
            TraceKind::Coherence => self.coherence_ops += 1,
            _ => {}
        }
        if self.enabled && self.events.len() < self.cap {
            self.events.push(ev);
        }
    }

    /// Convenience: record a transfer send.
    pub fn send(&mut self, time: SimTime, tag: &'static str, bytes: u64, dur: f64) {
        self.record(TraceEvent { time, kind: TraceKind::Send, tag, bytes, dur });
    }

    /// Total bytes injected into fabrics.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    /// Total bytes serviced by memory devices.
    pub fn bytes_mem(&self) -> u64 {
        self.bytes_mem
    }

    /// Total coherence protocol actions.
    pub fn coherence_ops(&self) -> u64 {
        self.coherence_ops
    }

    /// Retained records.
    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Count of retained records matching `kind`.
    pub fn count(&self, kind: TraceKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_accumulate() {
        let mut t = Trace::new(4);
        t.send(0.0, "a", 100, 1.0);
        t.send(1.0, "b", 50, 1.0);
        t.record(TraceEvent { time: 2.0, kind: TraceKind::MemAccess, tag: "m", bytes: 64, dur: 0.1 });
        t.record(TraceEvent { time: 3.0, kind: TraceKind::Coherence, tag: "inv", bytes: 0, dur: 0.0 });
        assert_eq!(t.bytes_sent(), 150);
        assert_eq!(t.bytes_mem(), 64);
        assert_eq!(t.coherence_ops(), 1);
    }

    #[test]
    fn cap_bounds_records_not_aggregates() {
        let mut t = Trace::new(2);
        for i in 0..10 {
            t.send(i as f64, "x", 10, 0.0);
        }
        assert_eq!(t.events().len(), 2);
        assert_eq!(t.bytes_sent(), 100);
    }

    #[test]
    fn aggregates_only_mode() {
        let mut t = Trace::aggregates_only();
        t.send(0.0, "x", 7, 0.0);
        assert!(t.events().is_empty());
        assert_eq!(t.bytes_sent(), 7);
    }
}
