//! Deterministic PRNG (splitmix64-seeded xoshiro256**).
//!
//! The published `rand` crate is not available in this offline build, so we
//! carry a small, well-known generator: xoshiro256** (Blackman & Vigna),
//! seeded through splitmix64 as its authors recommend. It is *not*
//! cryptographic; it exists for reproducible workload generation.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 works (including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Rng { s }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, n) (n > 0). Uses rejection-free Lemire reduction.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize index in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed sample with the given mean (for Poisson
    /// arrival processes in the serving workload generator).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = self.f64().max(1e-15);
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = self.f64().max(1e-15);
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Zipf-distributed index in [0, n) with skew `s` (embedding-table and
    /// KV-cache access skew). Uses the rejection-inversion method's simple
    /// cutoff approximation, adequate for workload generation.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        if s <= 0.0 {
            return self.index(n);
        }
        // Inverse-CDF on the harmonic approximation H(k) ~ k^(1-s)/(1-s).
        let u = self.f64();
        if (s - 1.0).abs() < 1e-9 {
            let hn = (n as f64).ln();
            return ((u * hn).exp() - 1.0).min((n - 1) as f64).max(0.0) as usize;
        }
        let e = 1.0 - s;
        let hn = ((n as f64).powf(e) - 1.0) / e;
        let k = (1.0 + u * hn * e).powf(1.0 / e) - 1.0;
        (k.max(0.0) as usize).min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent stream (for per-entity RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for n in [1u64, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(25.0)).sum::<f64>() / n as f64;
        assert!((mean - 25.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn zipf_skews_low_indices() {
        let mut r = Rng::new(17);
        let n = 10_000;
        let lows = (0..n).filter(|_| r.zipf(1000, 1.1) < 10).count();
        // heavily skewed: a large fraction of draws land in the first 1%.
        assert!(lows > n / 5, "lows={lows}");
    }

    #[test]
    fn zipf_zero_skew_uniformish() {
        let mut r = Rng::new(19);
        let n = 20_000;
        let lows = (0..n).filter(|_| r.zipf(1000, 0.0) < 100).count();
        let frac = lows as f64 / n as f64;
        assert!((frac - 0.1).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
