//! Discrete-event simulation core.
//!
//! Everything in the fabric/memory/workload layers runs on top of this
//! engine: a binary-heap event queue with a monotonically advancing
//! simulated clock (nanoseconds, `f64`), a deterministic PRNG for
//! reproducible experiments, streaming statistics, and an event trace.

pub mod engine;
pub mod rng;
pub mod stats;
pub mod trace;

pub use engine::{Engine, EventId, HookId, SimTime};
pub use rng::Rng;
pub use stats::{Percentiles, Summary, TimeWeighted};
pub use trace::{Trace, TraceEvent};
