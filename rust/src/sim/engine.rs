//! Binary-heap discrete-event engine.
//!
//! The engine owns a priority queue of `(time, seq, action)` events.
//! Determinism: ties on time are broken by insertion sequence number, so
//! two runs with the same seed produce identical traces.
//!
//! Events come in two shapes sharing one queue and one sequence counter:
//!
//! * **Boxed closures** — `FnOnce(&mut Engine)` scheduled via
//!   [`Engine::schedule_at`] / [`Engine::schedule_in`] / [`Engine::defer`].
//!   General-purpose, but each costs a fresh heap allocation.
//! * **Hook events** — the allocation-light lane for high-volume event
//!   shapes (flow completion timers, open-loop arrival ticks). A handler
//!   is registered **once** via [`Engine::register_hook`] (one `Rc`
//!   allocation, recycled for every firing) and then scheduled any number
//!   of times via [`Engine::schedule_hook_at`] /
//!   [`Engine::schedule_hook_in`] / [`Engine::defer_hook`], each carrying
//!   only a plain `u64` payload — no per-event `Box`.
//!
//! Both lanes draw from the same `next_seq` counter and compare with the
//! same `(time, seq)` order, so interleavings — and therefore golden
//! traces — are byte-identical to an all-boxed schedule.

use std::cell::RefCell;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};

/// Simulated time in nanoseconds.
pub type SimTime = f64;

/// Identifier assigned to each scheduled event (insertion order).
pub type EventId = u64;

/// Identifier of a handler registered with [`Engine::register_hook`].
pub type HookId = usize;

type Callback = Box<dyn FnOnce(&mut Engine)>;
type HookFn = Rc<RefCell<dyn FnMut(&mut Engine, u64)>>;

/// What a popped event does: run a one-shot boxed closure, or fire a
/// registered hook with its payload (no allocation on the schedule path).
enum Action {
    Boxed(Callback),
    Hook { hook: HookId, payload: u64 },
}

struct Event {
    time: SimTime,
    seq: EventId,
    act: Option<Action>,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert so the earliest event pops first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Process-unique engine identities, so long-lived components (e.g. a
/// [`crate::fabric::flow::FabricSim`] driven by several engines over its
/// lifetime) can tell whether their registered hooks belong to *this*
/// engine.
static ENGINE_IDS: AtomicU64 = AtomicU64::new(1);

/// Discrete-event simulation engine.
///
/// ```no_run
/// # // no_run: doctest binaries miss the xla_extension rpath; the same
/// # // scenario runs as a unit test (`nested_scheduling`) below.
/// use commtax::sim::Engine;
/// let mut eng = Engine::new();
/// eng.schedule_at(10.0, |e| {
///     let t = e.now();
///     e.schedule_in(5.0, move |e2| assert_eq!(e2.now(), t + 5.0));
/// });
/// eng.run();
/// assert_eq!(eng.now(), 15.0);
/// ```
pub struct Engine {
    now: SimTime,
    queue: BinaryHeap<Event>,
    next_seq: EventId,
    processed: u64,
    /// Optional hard stop; events beyond this time are not executed.
    horizon: Option<SimTime>,
    /// Registered hook handlers (slab: a `HookId` is an index here).
    hooks: Vec<HookFn>,
    id: u64,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    /// New engine with clock at t=0.
    pub fn new() -> Self {
        Engine {
            now: 0.0,
            queue: BinaryHeap::new(),
            next_seq: 0,
            processed: 0,
            horizon: None,
            hooks: Vec::new(),
            id: ENGINE_IDS.fetch_add(1, AtomicOrdering::Relaxed),
        }
    }

    /// Process-unique identity of this engine instance.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Current simulated time (ns).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of events still pending.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Stop the run loop once the clock would pass `t`.
    pub fn set_horizon(&mut self, t: SimTime) {
        self.horizon = Some(t);
    }

    fn push(&mut self, t: SimTime, act: Action) -> EventId {
        let t = if t < self.now { self.now } else { t };
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push(Event { time: t, seq, act: Some(act) });
        seq
    }

    /// Schedule `cb` at absolute time `t` (clamped to now if in the past).
    pub fn schedule_at<F: FnOnce(&mut Engine) + 'static>(&mut self, t: SimTime, cb: F) -> EventId {
        self.push(t, Action::Boxed(Box::new(cb)))
    }

    /// Schedule `cb` after a relative delay `dt >= 0`.
    pub fn schedule_in<F: FnOnce(&mut Engine) + 'static>(&mut self, dt: SimTime, cb: F) -> EventId {
        debug_assert!(dt >= 0.0, "negative delay {dt}");
        let now = self.now;
        self.schedule_at(now + dt.max(0.0), cb)
    }

    /// Schedule `cb` at the *current* instant, after every event already
    /// queued at this time (same-time ties break by insertion order, and
    /// this inserts last). The flow engine's same-timestamp admission
    /// batching hangs off this: activations sharing an instant enqueue
    /// work, and one deferred callback folds it into a single rate repair
    /// before simulated time can advance.
    pub fn defer<F: FnOnce(&mut Engine) + 'static>(&mut self, cb: F) -> EventId {
        let now = self.now;
        self.schedule_at(now, cb)
    }

    /// Register a reusable hook handler; the returned [`HookId`] can be
    /// scheduled any number of times with a `u64` payload and no per-event
    /// allocation. Handlers live as long as the engine.
    pub fn register_hook<F: FnMut(&mut Engine, u64) + 'static>(&mut self, f: F) -> HookId {
        self.hooks.push(Rc::new(RefCell::new(f)));
        self.hooks.len() - 1
    }

    /// Schedule hook `hook` to fire with `payload` at absolute time `t`
    /// (clamped to now if in the past). Allocation-free event push.
    pub fn schedule_hook_at(&mut self, t: SimTime, hook: HookId, payload: u64) -> EventId {
        debug_assert!(hook < self.hooks.len(), "unregistered hook {hook}");
        self.push(t, Action::Hook { hook, payload })
    }

    /// Schedule hook `hook` after a relative delay `dt >= 0`.
    pub fn schedule_hook_in(&mut self, dt: SimTime, hook: HookId, payload: u64) -> EventId {
        debug_assert!(dt >= 0.0, "negative delay {dt}");
        let now = self.now;
        self.schedule_hook_at(now + dt.max(0.0), hook, payload)
    }

    /// Hook twin of [`Engine::defer`]: fire `hook` at the current instant,
    /// after every event already queued at this time.
    pub fn defer_hook(&mut self, hook: HookId, payload: u64) -> EventId {
        let now = self.now;
        self.schedule_hook_at(now, hook, payload)
    }

    /// Execute a single event. Returns false when the queue is empty or the
    /// horizon has been reached.
    pub fn step(&mut self) -> bool {
        match self.queue.pop() {
            Some(mut ev) => {
                if let Some(h) = self.horizon {
                    if ev.time > h {
                        self.now = h;
                        return false;
                    }
                }
                debug_assert!(ev.time >= self.now, "time went backwards");
                self.now = ev.time;
                self.processed += 1;
                match ev.act.take() {
                    Some(Action::Boxed(cb)) => cb(self),
                    Some(Action::Hook { hook, payload }) => {
                        // clone the Rc out of the slab so the handler can
                        // take `&mut Engine` (and even register new hooks)
                        let h = self.hooks[hook].clone();
                        (h.borrow_mut())(self, payload);
                    }
                    None => {}
                }
                true
            }
            None => false,
        }
    }

    /// Run until the queue drains (or the horizon is hit).
    pub fn run(&mut self) {
        while self.step() {}
    }

    /// Run until `t`, leaving later events pending.
    pub fn run_until(&mut self, t: SimTime) {
        while let Some(ev) = self.queue.peek() {
            if ev.time > t {
                break;
            }
            self.step();
        }
        if self.now < t {
            self.now = t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn empty_engine_runs() {
        let mut e = Engine::new();
        e.run();
        assert_eq!(e.now(), 0.0);
        assert_eq!(e.processed(), 0);
    }

    #[test]
    fn events_fire_in_time_order() {
        let order = Rc::new(RefCell::new(Vec::new()));
        let mut e = Engine::new();
        for (i, t) in [(0u32, 30.0), (1, 10.0), (2, 20.0)] {
            let o = order.clone();
            e.schedule_at(t, move |_| o.borrow_mut().push(i));
        }
        e.run();
        assert_eq!(*order.borrow(), vec![1, 2, 0]);
        assert_eq!(e.now(), 30.0);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let order = Rc::new(RefCell::new(Vec::new()));
        let mut e = Engine::new();
        for i in 0..16u32 {
            let o = order.clone();
            e.schedule_at(5.0, move |_| o.borrow_mut().push(i));
        }
        e.run();
        assert_eq!(*order.borrow(), (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn nested_scheduling() {
        let hits = Rc::new(RefCell::new(0u32));
        let mut e = Engine::new();
        let h = hits.clone();
        e.schedule_at(1.0, move |eng| {
            *h.borrow_mut() += 1;
            let h2 = h.clone();
            eng.schedule_in(2.0, move |eng2| {
                assert_eq!(eng2.now(), 3.0);
                *h2.borrow_mut() += 1;
            });
        });
        e.run();
        assert_eq!(*hits.borrow(), 2);
    }

    #[test]
    fn defer_runs_after_queued_same_time_events() {
        // three events at t=1; the first defers a callback, which must run
        // after the two events already queued at the same instant — and
        // after anything those events themselves defer later
        let order = Rc::new(RefCell::new(Vec::new()));
        let mut e = Engine::new();
        for i in 0..3u32 {
            let o = order.clone();
            e.schedule_at(1.0, move |eng| {
                o.borrow_mut().push(i);
                if i == 0 {
                    let o2 = o.clone();
                    eng.defer(move |eng2| {
                        assert_eq!(eng2.now(), 1.0, "defer must not advance time");
                        o2.borrow_mut().push(10);
                    });
                }
            });
        }
        e.run();
        assert_eq!(*order.borrow(), vec![0, 1, 2, 10]);
        assert_eq!(e.now(), 1.0);
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut e = Engine::new();
        e.schedule_at(10.0, |eng| {
            eng.schedule_at(1.0, |eng2| assert_eq!(eng2.now(), 10.0));
        });
        e.run();
        assert_eq!(e.now(), 10.0);
    }

    #[test]
    fn horizon_stops_run() {
        let fired = Rc::new(RefCell::new(0u32));
        let mut e = Engine::new();
        e.set_horizon(15.0);
        for t in [5.0, 10.0, 20.0, 30.0] {
            let f = fired.clone();
            e.schedule_at(t, move |_| *f.borrow_mut() += 1);
        }
        e.run();
        assert_eq!(*fired.borrow(), 2);
        assert_eq!(e.now(), 15.0);
    }

    #[test]
    fn run_until_leaves_future_events() {
        let mut e = Engine::new();
        e.schedule_at(5.0, |_| {});
        e.schedule_at(50.0, |_| {});
        e.run_until(10.0);
        assert_eq!(e.now(), 10.0);
        assert_eq!(e.pending(), 1);
        e.run();
        assert_eq!(e.now(), 50.0);
    }

    #[test]
    fn engine_identities_are_unique() {
        let a = Engine::new();
        let b = Engine::new();
        assert_ne!(a.id(), b.id());
    }

    #[test]
    fn hook_events_interleave_with_boxed_in_insertion_order() {
        // same-time hook and boxed events must fire in exact insertion
        // order — the hook lane draws from the same seq counter
        let order = Rc::new(RefCell::new(Vec::new()));
        let mut e = Engine::new();
        let o = order.clone();
        let hook = e.register_hook(move |eng, p| {
            assert_eq!(eng.now(), 5.0);
            o.borrow_mut().push(p as u32);
        });
        for i in 0..8u32 {
            if i % 2 == 0 {
                e.schedule_hook_at(5.0, hook, i as u64);
            } else {
                let o = order.clone();
                e.schedule_at(5.0, move |_| o.borrow_mut().push(i));
            }
        }
        e.run();
        assert_eq!(*order.borrow(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn hook_can_reschedule_itself() {
        // self-rescheduling hook = the open-loop arrival tick shape
        let count = Rc::new(RefCell::new(0u64));
        let c = count.clone();
        let mut e = Engine::new();
        let hook = e.register_hook(move |eng, remaining| {
            *c.borrow_mut() += 1;
            if remaining > 1 {
                eng.schedule_hook_in(1.0, 0, remaining - 1);
            }
        });
        assert_eq!(hook, 0);
        e.schedule_hook_at(0.0, hook, 100);
        e.run();
        assert_eq!(*count.borrow(), 100);
        assert_eq!(e.now(), 99.0);
        assert_eq!(e.processed(), 100);
    }

    #[test]
    fn defer_hook_runs_after_queued_same_time_events() {
        let order = Rc::new(RefCell::new(Vec::new()));
        let mut e = Engine::new();
        let o = order.clone();
        let hook = e.register_hook(move |_, p| o.borrow_mut().push(p as u32));
        let (o2, h2) = (order.clone(), hook);
        e.schedule_at(1.0, move |eng| {
            o2.borrow_mut().push(0);
            eng.defer_hook(h2, 10);
        });
        let o3 = order.clone();
        e.schedule_at(1.0, move |_| o3.borrow_mut().push(1));
        e.schedule_hook_at(1.0, hook, 2);
        e.run();
        assert_eq!(*order.borrow(), vec![0, 1, 2, 10]);
        assert_eq!(e.now(), 1.0);
    }

    #[test]
    fn hooks_respect_horizon() {
        let fired = Rc::new(RefCell::new(0u32));
        let f = fired.clone();
        let mut e = Engine::new();
        let hook = e.register_hook(move |_, _| *f.borrow_mut() += 1);
        e.set_horizon(15.0);
        for t in [5.0, 10.0, 20.0] {
            e.schedule_hook_at(t, hook, 0);
        }
        e.run();
        assert_eq!(*fired.borrow(), 2);
        assert_eq!(e.now(), 15.0);
    }
}
