//! Streaming statistics: summaries and percentiles for experiment reports.

/// Accumulating summary over f64 samples.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sum: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Summary { samples: Vec::new(), sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Record one sample.
    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    /// Record many samples.
    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        for x in xs {
            self.add(x);
        }
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Sum of samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Arithmetic mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.sum / self.samples.len() as f64
        }
    }

    /// Sample standard deviation (0 if < 2 samples).
    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    }

    /// Minimum (inf if empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum (-inf if empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Percentile in [0, 100] by linear interpolation on the sorted sample.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_of_sorted(&sorted, p)
    }

    /// Common latency percentiles.
    pub fn percentiles(&self) -> Percentiles {
        if self.samples.is_empty() {
            return Percentiles::default();
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Percentiles {
            p50: percentile_of_sorted(&sorted, 50.0),
            p90: percentile_of_sorted(&sorted, 90.0),
            p95: percentile_of_sorted(&sorted, 95.0),
            p99: percentile_of_sorted(&sorted, 99.0),
        }
    }
}

fn percentile_of_sorted(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(n - 1)] * frac
}

/// p50/p90/p95/p99 bundle.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Piecewise-constant signal tracked over simulated time: call
/// [`TimeWeighted::set`] whenever the value changes and read back the
/// time-weighted mean and peak. Used for utilization-style telemetry
/// (active flows on a fabric, queue depths) where a plain sample mean
/// would over-weight busy bursts of events.
#[derive(Clone, Debug, Default)]
pub struct TimeWeighted {
    last_t: f64,
    last_v: f64,
    integral: f64,
    peak: f64,
}

impl TimeWeighted {
    /// Signal at value 0 from t=0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that the signal takes value `v` from time `t` onward.
    /// Out-of-order times are clamped (no negative intervals).
    pub fn set(&mut self, t: f64, v: f64) {
        if t > self.last_t {
            self.integral += self.last_v * (t - self.last_t);
            self.last_t = t;
        }
        self.last_v = v;
        if v > self.peak {
            self.peak = v;
        }
    }

    /// Time-weighted mean over [0, t] (0 when t <= 0).
    pub fn mean_until(&self, t: f64) -> f64 {
        if t <= 0.0 {
            return 0.0;
        }
        let tail = if t > self.last_t { self.last_v * (t - self.last_t) } else { 0.0 };
        (self.integral + tail) / t
    }

    /// Highest value ever set.
    pub fn peak(&self) -> f64 {
        self.peak
    }
}

/// Geometric mean of ratios (used for multi-workload speedup summaries).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let logs: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (logs / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_min_max() {
        let mut s = Summary::new();
        s.extend([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.count(), 4);
    }

    #[test]
    fn std_of_constant_is_zero() {
        let mut s = Summary::new();
        s.extend([5.0; 10]);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        s.extend((1..=100).map(|i| i as f64));
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn percentiles_ordered() {
        let mut s = Summary::new();
        s.extend((0..1000).map(|i| (i % 37) as f64));
        let p = s.percentiles();
        assert!(p.p50 <= p.p90 && p.p90 <= p.p95 && p.p95 <= p.p99);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
    }

    #[test]
    fn time_weighted_mean_and_peak() {
        let mut w = TimeWeighted::new();
        w.set(0.0, 2.0); // 2 over [0, 10)
        w.set(10.0, 6.0); // 6 over [10, 20)
        assert!((w.mean_until(20.0) - 4.0).abs() < 1e-12);
        assert_eq!(w.peak(), 6.0);
        // tail extension: still 6 over [20, 40)
        assert!((w.mean_until(40.0) - 5.0).abs() < 1e-12);
        assert_eq!(w.mean_until(0.0), 0.0);
    }

    #[test]
    fn geomean_of_ratios() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }
}
