//! Streaming statistics: summaries and percentiles for experiment reports.
//!
//! [`Summary`] is exact while small and bounded while huge: below a
//! configurable sample threshold it retains every sample and computes
//! percentiles on the sorted vector (byte-identical to the historical
//! behavior, so small-n tests and golden traces are unaffected); past the
//! threshold it folds the retained samples into a deterministic
//! Greenwald–Khanna quantile sketch with a uniform rank-error guarantee of
//! [`Summary::SKETCH_EPSILON`] (0.1% of n — comfortably inside the 0.5%
//! band the scenario suite pins) and drops the vector, so a million-request
//! open-loop run keeps O((1/ε)·log εn) state instead of one `f64` per
//! request. `count`/`sum`/`min`/`max`/`mean` stay exact in both regimes;
//! `std` switches to Welford's streaming recurrence in sketch mode.
//! [`Summary::exact`] opts out of sketching entirely (conservation tests).

/// Default retained-sample count above which a [`Summary`] switches from
/// the exact sorted path to the bounded-memory sketch.
const DEFAULT_SKETCH_THRESHOLD: usize = 8192;

/// Accumulating summary over f64 samples.
#[derive(Clone, Debug)]
pub struct Summary {
    /// Retained samples (exact regime only; emptied on sketch handoff).
    samples: Vec<f64>,
    sketch: Option<GkSketch>,
    threshold: usize,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// Welford running mean / M2, for `std()` once samples are dropped.
    w_mean: f64,
    w_m2: f64,
}

impl Default for Summary {
    fn default() -> Self {
        // mirrors the historically derived Default (zeroed min/max rather
        // than new()'s infinities) so zero-initialized holders keep their
        // exact observable behavior
        Summary {
            samples: Vec::new(),
            sketch: None,
            threshold: DEFAULT_SKETCH_THRESHOLD,
            count: 0,
            sum: 0.0,
            min: 0.0,
            max: 0.0,
            w_mean: 0.0,
            w_m2: 0.0,
        }
    }
}

impl Summary {
    /// Uniform rank-error bound of the sketch regime: a percentile query
    /// returns a sample whose true rank is within `ε·n` of the target.
    pub const SKETCH_EPSILON: f64 = 0.001;

    /// Empty summary (sketches past the default threshold).
    pub fn new() -> Self {
        Summary { min: f64::INFINITY, max: f64::NEG_INFINITY, ..Default::default() }
    }

    /// Empty summary that retains every sample forever — the escape hatch
    /// for byte-conservation tests and anything else that must stay exact
    /// at any n.
    pub fn exact() -> Self {
        Summary { threshold: usize::MAX, ..Self::new() }
    }

    /// Empty summary switching to the sketch once more than `threshold`
    /// samples have been retained.
    pub fn with_sketch_threshold(threshold: usize) -> Self {
        Summary { threshold, ..Self::new() }
    }

    /// True once this summary has handed its samples to the sketch.
    pub fn is_sketching(&self) -> bool {
        self.sketch.is_some()
    }

    /// Elements of state held for percentile queries (retained samples, or
    /// sketch tuples + insert buffer). Bounded in sketch mode regardless
    /// of `count` — the observable the scale tests pin.
    pub fn retained(&self) -> usize {
        match &self.sketch {
            Some(sk) => sk.tuples.len() + sk.buf.len(),
            None => self.samples.len(),
        }
    }

    /// Record one sample.
    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        let d = x - self.w_mean;
        self.w_mean += d / self.count as f64;
        self.w_m2 += d * (x - self.w_mean);
        if let Some(sk) = self.sketch.as_mut() {
            sk.insert(x);
        } else {
            self.samples.push(x);
            if self.samples.len() > self.threshold {
                let mut sk = GkSketch::new(Self::SKETCH_EPSILON);
                for &v in &self.samples {
                    sk.insert(v);
                }
                self.samples = Vec::new();
                self.sketch = Some(sk);
            }
        }
    }

    /// Record many samples.
    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        for x in xs {
            self.add(x);
        }
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.count as usize
    }

    /// Sum of samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Arithmetic mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Sample standard deviation (0 if < 2 samples). Two-pass over the
    /// retained samples in the exact regime (bit-compatible with the
    /// historical formula); Welford in the sketch regime.
    pub fn std(&self) -> f64 {
        let n = self.count;
        if n < 2 {
            return 0.0;
        }
        if self.sketch.is_none() {
            let m = self.mean();
            let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64;
            return var.sqrt();
        }
        (self.w_m2 / (n - 1) as f64).sqrt()
    }

    /// Minimum (inf if empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum (-inf if empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Percentile in [0, 100]: linear interpolation on the sorted sample
    /// in the exact regime, a sketch query (≤ [`Self::SKETCH_EPSILON`]
    /// rank error) past the threshold. For several cuts prefer one
    /// [`Self::percentiles`] snapshot — it sorts/flushes once.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if let Some(sk) = &self.sketch {
            return self.sketch_cut(&sk.flushed(), p);
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_of_sorted(&sorted, p)
    }

    /// Common latency percentiles, computed from one sorted (or flushed)
    /// snapshot — never once per cut.
    pub fn percentiles(&self) -> Percentiles {
        if self.count == 0 {
            return Percentiles::default();
        }
        if let Some(sk) = &self.sketch {
            let snap = sk.flushed();
            return Percentiles {
                p50: self.sketch_cut(&snap, 50.0),
                p90: self.sketch_cut(&snap, 90.0),
                p95: self.sketch_cut(&snap, 95.0),
                p99: self.sketch_cut(&snap, 99.0),
                p999: self.sketch_cut(&snap, 99.9),
            };
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Percentiles {
            p50: percentile_of_sorted(&sorted, 50.0),
            p90: percentile_of_sorted(&sorted, 90.0),
            p95: percentile_of_sorted(&sorted, 95.0),
            p99: percentile_of_sorted(&sorted, 99.0),
            p999: percentile_of_sorted(&sorted, 99.9),
        }
    }

    /// One sketch cut, with exact endpoints (the sketch keeps the global
    /// min/max tuples, but p=0/100 deserve the tracked exact extremes).
    fn sketch_cut(&self, snap: &GkSketch, p: f64) -> f64 {
        if p <= 0.0 {
            return self.min;
        }
        if p >= 100.0 {
            return self.max;
        }
        snap.query(p).clamp(self.min, self.max)
    }
}

fn percentile_of_sorted(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(n - 1)] * frac
}

/// p50/p90/p95/p99/p999 bundle.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
}

/// Deterministic Greenwald–Khanna ε-approximate quantile sketch.
///
/// Maintains sorted tuples `(v, g, Δ)` where `g` is the rank gap to the
/// previous tuple and `Δ` bounds the rank uncertainty, with the invariant
/// `g + Δ ≤ ⌊2εn⌋` — which guarantees any quantile query lands within
/// `εn` ranks of the target. Inserts are buffered and merged in sorted
/// batches so the amortized per-sample cost is O(log B) instead of one
/// O(s) memmove each. Fully deterministic (no randomness), so summaries
/// feeding golden traces stay byte-identical across runs.
#[derive(Clone, Debug)]
struct GkSketch {
    eps: f64,
    /// Samples folded into `tuples` so far.
    n: u64,
    tuples: Vec<GkTuple>,
    buf: Vec<f64>,
}

#[derive(Clone, Copy, Debug)]
struct GkTuple {
    v: f64,
    g: u64,
    delta: u64,
}

/// Insert-buffer capacity: amortizes the O(s + B) batch merge down to a
/// few operations per sample.
const GK_BUF: usize = 512;

impl GkSketch {
    fn new(eps: f64) -> Self {
        GkSketch { eps, n: 0, tuples: Vec::new(), buf: Vec::with_capacity(GK_BUF) }
    }

    fn insert(&mut self, x: f64) {
        self.buf.push(x);
        if self.buf.len() >= GK_BUF {
            self.flush();
        }
    }

    /// Self with any buffered inserts folded in (queries need a fully
    /// merged tuple list; clone-to-flush keeps the query path `&self`).
    fn flushed(&self) -> GkSketch {
        if self.buf.is_empty() {
            return self.clone();
        }
        let mut c = self.clone();
        c.flush();
        c
    }

    /// Merge the sorted buffer into the tuple list in one pass, then
    /// compress under the invariant.
    fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        self.buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n_after = self.n + self.buf.len() as u64;
        let cap = (2.0 * self.eps * n_after as f64).floor() as u64;
        let new_delta = cap.saturating_sub(1);
        let mut merged: Vec<GkTuple> = Vec::with_capacity(self.tuples.len() + self.buf.len());
        let (mut ti, mut bi) = (0usize, 0usize);
        while ti < self.tuples.len() || bi < self.buf.len() {
            let take_tuple = match (self.tuples.get(ti), self.buf.get(bi)) {
                (Some(t), Some(&b)) => t.v <= b,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => unreachable!(),
            };
            if take_tuple {
                merged.push(self.tuples[ti]);
                ti += 1;
            } else {
                merged.push(GkTuple { v: self.buf[bi], g: 1, delta: new_delta });
                bi += 1;
            }
        }
        // the global extremes have exactly-known ranks
        if let Some(first) = merged.first_mut() {
            first.delta = 0;
        }
        if let Some(last) = merged.last_mut() {
            last.delta = 0;
        }
        self.n = n_after;
        self.tuples = merged;
        self.buf.clear();
        self.compress(cap);
    }

    /// Fold tuples into their successor while `g_i + g_{i+1} + Δ_{i+1}`
    /// stays under the invariant cap; the min tuple always survives.
    fn compress(&mut self, cap: u64) {
        let mut out: Vec<GkTuple> = Vec::with_capacity(self.tuples.len());
        for t in self.tuples.drain(..) {
            if let Some(prev) = out.last() {
                if out.len() > 1 && prev.g + t.g + t.delta <= cap {
                    let prev = out.pop().expect("non-empty");
                    let mut t = t;
                    t.g += prev.g;
                    out.push(t);
                    continue;
                }
            }
            out.push(t);
        }
        self.tuples = out;
    }

    /// Value whose rank is within `εn` of the `p`-percentile rank.
    /// Requires a flushed sketch (`buf` empty).
    fn query(&self, p: f64) -> f64 {
        debug_assert!(self.buf.is_empty(), "query on unflushed sketch");
        if self.tuples.is_empty() {
            return 0.0;
        }
        let n = self.n as f64;
        // 1-based target rank, matching the exact path's interpolation
        // anchor (p/100)·(n−1)
        let rank = (p.clamp(0.0, 100.0) / 100.0) * (n - 1.0) + 1.0;
        let e = self.eps * n;
        let mut rmin = 0u64;
        for (i, t) in self.tuples.iter().enumerate() {
            rmin += t.g;
            match self.tuples.get(i + 1) {
                Some(nx) => {
                    if (rmin + nx.g + nx.delta) as f64 > rank + e {
                        return t.v;
                    }
                }
                None => return t.v,
            }
        }
        self.tuples[self.tuples.len() - 1].v
    }
}

/// Piecewise-constant signal tracked over simulated time: call
/// [`TimeWeighted::set`] whenever the value changes and read back the
/// time-weighted mean and peak. Used for utilization-style telemetry
/// (active flows on a fabric, queue depths) where a plain sample mean
/// would over-weight busy bursts of events.
#[derive(Clone, Debug, Default)]
pub struct TimeWeighted {
    last_t: f64,
    last_v: f64,
    integral: f64,
    peak: f64,
}

impl TimeWeighted {
    /// Signal at value 0 from t=0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that the signal takes value `v` from time `t` onward.
    /// Out-of-order times are clamped (no negative intervals).
    pub fn set(&mut self, t: f64, v: f64) {
        if t > self.last_t {
            self.integral += self.last_v * (t - self.last_t);
            self.last_t = t;
        }
        self.last_v = v;
        if v > self.peak {
            self.peak = v;
        }
    }

    /// Time-weighted mean over [0, t] (0 when t <= 0).
    pub fn mean_until(&self, t: f64) -> f64 {
        if t <= 0.0 {
            return 0.0;
        }
        let tail = if t > self.last_t { self.last_v * (t - self.last_t) } else { 0.0 };
        (self.integral + tail) / t
    }

    /// Highest value ever set.
    pub fn peak(&self) -> f64 {
        self.peak
    }
}

/// Geometric mean of ratios (used for multi-workload speedup summaries).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let logs: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (logs / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;

    #[test]
    fn mean_min_max() {
        let mut s = Summary::new();
        s.extend([1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.count(), 4);
    }

    #[test]
    fn std_of_constant_is_zero() {
        let mut s = Summary::new();
        s.extend([5.0; 10]);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        s.extend((1..=100).map(|i| i as f64));
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn percentiles_ordered() {
        let mut s = Summary::new();
        s.extend((0..1000).map(|i| (i % 37) as f64));
        let p = s.percentiles();
        assert!(p.p50 <= p.p90 && p.p90 <= p.p95 && p.p95 <= p.p99 && p.p99 <= p.p999);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
    }

    #[test]
    fn exact_summary_never_sketches() {
        let mut s = Summary::exact();
        s.extend((0..50_000).map(|i| i as f64));
        assert!(!s.is_sketching());
        assert_eq!(s.retained(), 50_000);
        assert!((s.percentile(50.0) - 24_999.5).abs() < 1e-6);
    }

    #[test]
    fn sketch_engages_past_threshold_with_bounded_state() {
        let mut s = Summary::with_sketch_threshold(1000);
        let mut r = Rng::new(7);
        for _ in 0..200_000 {
            s.add(r.f64() * 1.0e6);
        }
        assert!(s.is_sketching());
        assert_eq!(s.count(), 200_000);
        // bounded: orders of magnitude below the sample count
        assert!(s.retained() < 20_000, "retained {}", s.retained());
        let p = s.percentiles();
        assert!(p.p50 <= p.p90 && p.p90 <= p.p99 && p.p99 <= p.p999);
        assert_eq!(s.percentile(0.0), s.min());
        assert_eq!(s.percentile(100.0), s.max());
    }

    #[test]
    fn sketch_percentiles_within_rank_error_band() {
        // rank error of each sketch cut vs the exact sorted data must stay
        // within the pinned band (0.5% of n; the sketch promises 0.1%)
        let mut sketch = Summary::with_sketch_threshold(512);
        let mut exact: Vec<f64> = Vec::new();
        let mut r = Rng::new(42);
        let n = 60_000usize;
        for _ in 0..n {
            // heavy-tailed-ish mixture, the shape latency data takes
            let x = if r.chance(0.05) { r.f64() * 5.0e7 } else { r.exp(1.0e6) };
            sketch.add(x);
            exact.push(x);
        }
        exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [10.0, 50.0, 90.0, 99.0, 99.9] {
            let v = sketch.percentile(p);
            let rank = exact.partition_point(|&x| x < v) as f64;
            let target = (p / 100.0) * (n - 1) as f64 + 1.0;
            let err = (rank - target).abs() / n as f64;
            assert!(err <= 0.005, "p{p}: rank {rank} vs target {target} (err {err})");
        }
    }

    #[test]
    fn sketch_mean_sum_std_stay_sane() {
        let mut s = Summary::with_sketch_threshold(100);
        let mut exact_v: Vec<f64> = Vec::new();
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.normal(500.0, 25.0);
            s.add(x);
            exact_v.push(x);
        }
        let n = exact_v.len() as f64;
        let mean = exact_v.iter().sum::<f64>() / n;
        let var = exact_v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
        assert!((s.mean() - mean).abs() < 1e-9);
        assert!((s.std() - var.sqrt()).abs() / var.sqrt() < 1e-9);
    }

    #[test]
    fn sketch_is_deterministic() {
        let feed = |seed| {
            let mut s = Summary::with_sketch_threshold(256);
            let mut r = Rng::new(seed);
            for _ in 0..30_000 {
                s.add(r.exp(2.0e6));
            }
            let p = s.percentiles();
            (p.p50.to_bits(), p.p99.to_bits(), p.p999.to_bits())
        };
        assert_eq!(feed(9), feed(9));
        assert_ne!(feed(9), feed(10));
    }

    #[test]
    fn time_weighted_mean_and_peak() {
        let mut w = TimeWeighted::new();
        w.set(0.0, 2.0); // 2 over [0, 10)
        w.set(10.0, 6.0); // 6 over [10, 20)
        assert!((w.mean_until(20.0) - 4.0).abs() < 1e-12);
        assert_eq!(w.peak(), 6.0);
        // tail extension: still 6 over [20, 40)
        assert!((w.mean_until(40.0) - 5.0).abs() < 1e-12);
        assert_eq!(w.mean_until(0.0), 0.0);
    }

    #[test]
    fn geomean_of_ratios() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }
}
