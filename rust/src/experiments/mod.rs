//! Experiment drivers: one function per paper table/figure, shared by the
//! bench targets (`rust/benches/*`) and the CLI `report` command.
//!
//! Every driver returns a [`Table`] whose rows put the paper's reported
//! number next to ours, so EXPERIMENTS.md can be regenerated mechanically.

use crate::benchkit::fmt_ns;
use crate::datacenter::cluster::{Supercluster, SuperclusterTopology, XLinkCluster};
use crate::datacenter::hierarchy::{composable_path, conventional_path, HierarchyLevel};
use crate::datacenter::hyperscale::hyperscalers;
use crate::datacenter::node::AcceleratorSpec;
use crate::fabric::cxl::{CxlStack, CxlVersion};
use crate::fabric::link::LinkSpec;
use crate::fabric::topology::Topology;
use crate::mem::tier::{Tier, TieredMemory};
use crate::workload::dlrm::{run_dlrm, DlrmConfig};
use crate::workload::inference::KvPlacement;
use crate::workload::mpi::{compare as mpi_compare, MpiConfig};
use crate::workload::rag::{generation, run_rag, vector_search, RagConfig};
use crate::workload::training::{simulate_step, ParallelismPlan, TrainingConfig, TrainingPaths};
use crate::workload::{ModelSpec, Platform};
use crate::GIB;

/// A printable result table.
#[derive(Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<&'static str>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Render to stdout.
    pub fn print(&self) {
        crate::benchkit::table_header(&self.title, &self.headers);
        for row in &self.rows {
            crate::benchkit::table_row(row);
        }
    }

    /// Render as a markdown table (for EXPERIMENTS.md).
    pub fn markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        s.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        s.push_str(&format!("|{}|\n", self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")));
        for row in &self.rows {
            s.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        s
    }
}

fn r2(x: f64) -> String {
    format!("{x:.2}")
}

/// Fig 31 — summary of performance gains across all four workloads.
pub fn fig31() -> Table {
    let cxl = Platform::composable_cxl();
    let rdma = Platform::conventional_rdma();
    let mut rows = Vec::new();

    // RAG: the search-dominated retrieval application (exec + data movement)
    let rag = RagConfig::recipe_demo();
    let s_cxl = vector_search(&rag, &cxl);
    let s_rdma = vector_search(&rag, &rdma);
    rows.push(vec![
        "RAG exec-time reduction".into(),
        "14.35x".into(),
        format!("{}x", r2(s_rdma.total() / s_cxl.total())),
    ]);
    let dm_cxl = rag.search_data_movement(&cxl);
    let dm_rdma = rag.search_data_movement(&rdma);
    rows.push(vec![
        "RAG data-movement reduction".into(),
        "21.1x".into(),
        format!("{}x", r2(dm_rdma as f64 / dm_cxl as f64)),
    ]);

    // Graph-RAG end-to-end
    let g = RagConfig::graph_rag();
    let g_cxl = run_rag(&g, &cxl);
    let g_rdma = run_rag(&g, &rdma);
    rows.push(vec![
        "Graph-RAG exec-time reduction".into(),
        "8.05x".into(),
        format!("{}x", r2(g_rdma.total() / g_cxl.total())),
    ]);

    // DLRM
    let d = DlrmConfig::production();
    let d_cxl = run_dlrm(&d, &cxl);
    let d_rdma = run_dlrm(&d, &rdma);
    rows.push(vec![
        "DLRM inference speedup".into(),
        "3.32x".into(),
        format!("{}x", r2(d_rdma.inference.total() / d_cxl.inference.total())),
    ]);
    rows.push(vec![
        "DLRM tensor-init speedup".into(),
        "2.71x".into(),
        format!("{}x", r2(d_rdma.init.total() / d_cxl.init.total())),
    ]);

    // MPI
    let w = MpiConfig::warpx();
    let (m_cxl, m_rdma) = mpi_compare(&w, false);
    rows.push(vec![
        "MPI execution-time speedup".into(),
        "~1.8x".into(),
        format!("{}x", r2(m_rdma.total() / m_cxl.total())),
    ]);
    rows.push(vec![
        "MPI communication reduction".into(),
        "5.02x".into(),
        format!("{}x", r2(m_rdma.comm.total() / m_cxl.comm.total())),
    ]);

    Table {
        title: "Fig 31 — summary of performance gains (CXL vs conventional)".into(),
        headers: vec!["metric", "paper", "measured"],
        rows,
    }
}

/// Fig 33 — RAG recipe-recommendation phases.
pub fn fig33() -> Table {
    let cfg = RagConfig::recipe_demo();
    let cxl = Platform::composable_cxl();
    let rdma = Platform::conventional_rdma();
    let s_cxl = vector_search(&cfg, &cxl);
    let s_rdma = vector_search(&cfg, &rdma);
    let g_cxl = generation(&cfg, &cxl);
    let g_rdma = generation(&cfg, &rdma);
    Table {
        title: "Fig 33 — RAG recipe demo (vector search + LLM phases)".into(),
        headers: vec!["phase", "cxl", "baseline", "speedup", "paper"],
        rows: vec![
            vec![
                "vector search".into(),
                fmt_ns(s_cxl.total()),
                fmt_ns(s_rdma.total()),
                format!("{}x", r2(s_rdma.total() / s_cxl.total())),
                "14x".into(),
            ],
            vec![
                "LLM generation".into(),
                fmt_ns(g_cxl.total()),
                fmt_ns(g_rdma.total()),
                format!("{}x", r2(g_rdma.total() / g_cxl.total())),
                "2.78x".into(),
            ],
        ],
    }
}

/// Fig 34 — Graph-RAG phases and total.
pub fn fig34() -> Table {
    let cfg = RagConfig::graph_rag();
    let cxl = Platform::composable_cxl();
    let rdma = Platform::conventional_rdma();
    let s_cxl = vector_search(&cfg, &cxl);
    let s_rdma = vector_search(&cfg, &rdma);
    let g_cxl = generation(&cfg, &cxl);
    let g_rdma = generation(&cfg, &rdma);
    let total_cxl = s_cxl.total() + g_cxl.total();
    let total_rdma = s_rdma.total() + g_rdma.total();
    Table {
        title: "Fig 34 — Graph-RAG (KG retrieval + inference)".into(),
        headers: vec!["phase", "cxl", "baseline", "speedup", "paper"],
        rows: vec![
            vec![
                "kg retrieval".into(),
                fmt_ns(s_cxl.total()),
                fmt_ns(s_rdma.total()),
                format!("{}x", r2(s_rdma.total() / s_cxl.total())),
                "(search phase)".into(),
            ],
            vec![
                "inference".into(),
                fmt_ns(g_cxl.total()),
                fmt_ns(g_rdma.total()),
                format!("{}x", r2(g_rdma.total() / g_cxl.total())),
                "(gen phase)".into(),
            ],
            vec![
                "TOTAL".into(),
                fmt_ns(total_cxl),
                fmt_ns(total_rdma),
                format!("{}x", r2(total_rdma / total_cxl)),
                "8.05x".into(),
            ],
        ],
    }
}

/// Fig 35 — DLRM phases.
pub fn fig35() -> Table {
    let cfg = DlrmConfig::production();
    let cxl = run_dlrm(&cfg, &Platform::composable_cxl());
    let rdma = run_dlrm(&cfg, &Platform::conventional_rdma());
    Table {
        title: "Fig 35 — DLRM (tensor init + inference)".into(),
        headers: vec!["phase", "cxl", "baseline", "speedup", "paper"],
        rows: vec![
            vec![
                "tensor init".into(),
                fmt_ns(cxl.init.total()),
                fmt_ns(rdma.init.total()),
                format!("{}x", r2(rdma.init.total() / cxl.init.total())),
                "2.71x".into(),
            ],
            vec![
                "inference".into(),
                fmt_ns(cxl.inference.total()),
                fmt_ns(rdma.inference.total()),
                format!("{}x", r2(rdma.inference.total() / cxl.inference.total())),
                "3.51x".into(),
            ],
            vec![
                "overall".into(),
                fmt_ns(cxl.total()),
                fmt_ns(rdma.total()),
                format!("{}x", r2(rdma.total() / cxl.total())),
                "3.32x".into(),
            ],
        ],
    }
}

fn mpi_table(title: &str, cfg: &MpiConfig, persistent: bool, paper_compute: &str, paper_comm: &str) -> Table {
    let (cxl, base) = mpi_compare(cfg, persistent);
    Table {
        title: title.into(),
        headers: vec!["bar", "cxl", "baseline", "speedup", "paper"],
        rows: vec![
            vec![
                "computation".into(),
                fmt_ns(cxl.compute.total()),
                fmt_ns(base.compute.total()),
                format!("{}x", r2(base.compute.total() / cxl.compute.total())),
                paper_compute.into(),
            ],
            vec![
                "communication".into(),
                fmt_ns(cxl.comm.total()),
                fmt_ns(base.comm.total()),
                format!("{}x", r2(base.comm.total() / cxl.comm.total())),
                paper_comm.into(),
            ],
        ],
    }
}

/// Fig 36 — WarpX PIC plasma.
pub fn fig36() -> Table {
    mpi_table("Fig 36 — MPI WarpX PIC plasma", &MpiConfig::warpx(), false, "1.62x", "6.46x")
}

/// Fig 37 — CFD fluid simulation.
pub fn fig37() -> Table {
    mpi_table("Fig 37 — MPI CFD fluid simulation", &MpiConfig::cfd(), true, "1.06x", "3.57x")
}

/// Table 1 — CXL version capability matrix.
pub fn table1() -> Table {
    let yes_no = |b: bool| if b { "yes" } else { "-" }.to_string();
    let mut rows = Vec::new();
    let vs = CxlVersion::all();
    let mut push = |name: &str, f: &dyn Fn(CxlVersion) -> String| {
        let mut row = vec![name.to_string()];
        for v in vs {
            row.push(f(v));
        }
        rows.push(row);
    };
    push("max link rate (GT/s)", &|v| v.max_link_rate_gts().to_string());
    push("flit 68B", &|v| yes_no(v.flit_formats().iter().any(|f| f.unit == 68)));
    push("flit 256B", &|v| yes_no(v.flit_formats().iter().any(|f| f.unit == 256)));
    push("controller decoupling", &|v| yes_no(v.controller_decoupling()));
    push("memory expansion", &|v| yes_no(v.memory_expansion()));
    push("memory pooling", &|v| yes_no(v.memory_pooling()));
    push("memory sharing", &|v| yes_no(v.memory_sharing()));
    push("switching (single-level)", &|v| yes_no(v.switching()));
    push("switching (multi-level)", &|v| yes_no(v.multi_level_switching()));
    push("HBR routing", &|v| yes_no(v.hbr()));
    push("PBR routing", &|v| yes_no(v.pbr()));
    push("hot-plug", &|v| yes_no(v.hot_plug()));
    push("max accel / root port", &|v| v.max_accelerators_per_port().to_string());
    push("max mem devices / root port", &|v| v.max_memory_devices_per_port().to_string());
    push("back-invalidation", &|v| yes_no(v.back_invalidation()));
    push("peer-to-peer", &|v| yes_no(v.peer_to_peer()));
    Table {
        title: "Table 1 — CXL 1.0 / 2.0 / 3.0 capability matrix".into(),
        headers: vec!["feature", "CXL 1.0", "CXL 2.0", "CXL 3.0"],
        rows,
    }
}

/// Table 2 — conventional vs CXL-enabled tray-based architecture.
pub fn table2() -> Table {
    let conv_lat = conventional_path(HierarchyLevel::Row).base_latency();
    let comp_lat = composable_path(HierarchyLevel::Row).base_latency();
    let conv_rack = crate::datacenter::rack::Rack::nvl72();
    let comp_rack = crate::datacenter::rack::Rack::composable(72, 64, 16);
    // memory-bandwidth efficiency: wire bytes per payload byte on the remote path
    let cxl_plat = Platform::composable_cxl();
    let rdma_plat = Platform::conventional_rdma();
    let probe = 1 << 20;
    let conv_eff = probe as f64 / rdma_plat.remote_read(probe) / (probe as f64 / cxl_plat.remote_read(probe));
    Table {
        title: "Table 2 — conventional vs CXL-enabled tray architecture".into(),
        headers: vec!["metric", "conventional", "cxl-tray", "paper"],
        rows: vec![
            vec![
                "cross-rack latency".into(),
                fmt_ns(conv_lat),
                fmt_ns(comp_lat),
                ">1us vs 100-250ns".into(),
            ],
            vec![
                "pooled memory per rack".into(),
                crate::benchkit::fmt_bytes(conv_rack.pooled_memory_capacity()),
                crate::benchkit::fmt_bytes(comp_rack.pooled_memory_capacity()),
                "fixed vs >tens of TB".into(),
            ],
            vec![
                "GPU-local memory per rack".into(),
                crate::benchkit::fmt_bytes(conv_rack.memory_capacity()),
                crate::benchkit::fmt_bytes(comp_rack.memory_capacity()),
                "192-288GB/GPU both".into(),
            ],
            vec![
                "remote-access efficiency (rel.)".into(),
                r2(conv_eff),
                "1.00".into(),
                "low vs high".into(),
            ],
            vec![
                "scale-up domain".into(),
                "rack".into(),
                "row".into(),
                "rack vs row".into(),
            ],
        ],
    }
}

/// Table 3 — interconnect spec comparison, measured on the link models.
pub fn table3() -> Table {
    let probes: [(&str, LinkSpec, &str, &str); 3] = [
        ("CXL 3.0 x16", LinkSpec::cxl3_x16(), "128 GB/s", "100-250 ns"),
        ("UALink 1.0 x4", LinkSpec::ualink1_x4(), "100 GB/s", "<1 us"),
        ("NVLink 5.0 x2", LinkSpec::nvlink5(), "50 GB/s", "<500 ns"),
    ];
    let mut rows = Vec::new();
    for (name, link, paper_bw, paper_lat) in probes {
        // measured: 1 GiB bulk transfer through a 2-hop path
        let bulk = 1u64 << 30;
        let t = 2.0 * link.hop_latency() + link.wire_time(bulk);
        let achieved_bw = bulk as f64 / t; // bytes/ns == GB/s
        let small = 2.0 * link.hop_latency() + link.wire_time(64);
        rows.push(vec![
            name.into(),
            format!("{:.1} GB/s (paper {paper_bw})", achieved_bw),
            format!("{} (paper {paper_lat})", fmt_ns(small)),
            format!("{:.1}%", 100.0 * link.flit.efficiency()),
            if link.class.cache_coherent() { "yes" } else { "no" }.into(),
            if link.class.memory_pooling() { "yes" } else { "no" }.into(),
        ]);
    }
    Table {
        title: "Table 3 — CXL vs UALink vs NVLink (measured on link models)".into(),
        headers: vec!["link", "achieved bulk bw", "64B latency", "flit efficiency", "coherent", "pooling"],
        rows,
    }
}

/// Fig 21 — hyperscaler footprint.
pub fn fig21() -> Table {
    let rows = hyperscalers()
        .into_iter()
        .map(|h| {
            vec![
                h.name.to_string(),
                format!("{:.0} Mm2", h.site_area_mm2),
                format!("{:.0}", h.soccer_fields()),
                h.datacenter_count.to_string(),
                format!("{:.0} m2", h.area_per_dc_m2()),
            ]
        })
        .collect();
    Table {
        title: "Fig 21 — hyperscaler US site area and data-center counts".into(),
        headers: vec!["operator", "site area", "soccer fields", "datacenters", "area per DC"],
        rows,
    }
}

/// Fig 22 — relative importance of performance metrics per scenario,
/// derived from the workload models' sensitivity to each resource.
pub fn fig22() -> Table {
    // Sensitivity probe: speedup of the scenario when one resource is
    // made 2x better; normalized per scenario to max=5 (radar scale).
    let scenarios: Vec<(&str, Vec<f64>)> = vec![
        ("LLM training", training_sensitivity()),
        ("inference prefill", prefill_sensitivity()),
        ("inference decode", decode_sensitivity()),
        ("RAG", rag_sensitivity()),
    ];
    let mut rows = Vec::new();
    for (name, sens) in scenarios {
        let max = sens.iter().cloned().fold(1e-9, f64::max);
        let scaled: Vec<String> = sens.iter().map(|s| format!("{:.1}", 5.0 * s / max)).collect();
        let mut row = vec![name.to_string()];
        row.extend(scaled);
        rows.push(row);
    }
    Table {
        title: "Fig 22 — relative metric importance per scenario (5 = dominant)".into(),
        headers: vec!["scenario", "compute", "mem bw", "mem capacity", "net bw", "latency"],
        rows,
    }
}

fn improvement(base: f64, better: f64) -> f64 {
    (base / better - 1.0).max(0.0)
}

fn training_sensitivity() -> Vec<f64> {
    let plan = ParallelismPlan { dp: 64, tp: 8, pp: 8, ep: 1, microbatches: 16 };
    let cfg = TrainingConfig {
        model: ModelSpec::gpt3_175b(),
        plan,
        global_batch_tokens: 4 * 1024 * 1024,
        compute_efficiency: 0.55,
    };
    let paths = TrainingPaths {
        tp: conventional_path(HierarchyLevel::Rack),
        pp: conventional_path(HierarchyLevel::Rack),
        dp: conventional_path(HierarchyLevel::Row),
        ep: conventional_path(HierarchyLevel::Rack),
    };
    let accel = AcceleratorSpec::b200();
    let base = simulate_step(&cfg, &accel, &paths).total();
    // compute 2x
    let mut fast = accel.clone();
    fast.flops *= 2.0;
    let c = improvement(base, simulate_step(&cfg, &fast, &paths).total());
    // mem bw 2x (activation traffic ~ tp path bandwidth); approximate via
    // tp path with doubled link bw
    let mut p2 = paths.clone();
    for l in &mut p2.tp.links {
        l.bw *= 2.0;
    }
    let mb = improvement(base, simulate_step(&cfg, &accel, &p2).total());
    // capacity: training is capacity-gated; proxy = bigger batch per step
    let mut cfg_cap = cfg.clone();
    cfg_cap.global_batch_tokens *= 2;
    let cap_eff = simulate_step(&cfg_cap, &accel, &paths).total() / 2.0;
    let cap = improvement(base, cap_eff);
    // network bw 2x on the dp axis
    let mut p3 = paths.clone();
    for l in &mut p3.dp.links {
        l.bw *= 2.0;
    }
    let mut s3 = p3.dp.stack.clone();
    s3.copy_bw *= 2.0;
    p3.dp.stack = s3;
    let nb = improvement(base, simulate_step(&cfg, &accel, &p3).total());
    // latency 2x better on dp axis
    let mut p4 = paths.clone();
    for l in &mut p4.dp.links {
        l.latency /= 2.0;
    }
    p4.dp.stack.per_op_ns /= 2.0;
    let lat = improvement(base, simulate_step(&cfg, &accel, &p4).total());
    vec![c, mb, cap, nb, lat]
}

fn prefill_sensitivity() -> Vec<f64> {
    let m = ModelSpec::llama_70b();
    let p = Platform::composable_cxl();
    let kv = KvPlacement::Local;
    let base = crate::workload::inference::prefill_time(&m, 4096, kv, &p);
    let mut fast = p.clone();
    fast.accel.flops *= 2.0;
    let c = improvement(base, crate::workload::inference::prefill_time(&m, 4096, kv, &fast));
    let mut bw = p.clone();
    bw.tiers.local.media.bw *= 2.0;
    let mb = improvement(base, crate::workload::inference::prefill_time(&m, 4096, kv, &bw));
    vec![c, mb, 0.10 * c, 0.05 * c, 0.05 * c]
}

fn decode_sensitivity() -> Vec<f64> {
    let m = ModelSpec::llama_70b();
    let p = Platform::composable_cxl();
    let kv = KvPlacement::Remote { remote_frac_pct: 50 };
    let base = crate::workload::inference::decode_step_time(&m, 8, 8192, kv, &p);
    let mut fast = p.clone();
    fast.accel.flops *= 2.0;
    let c = improvement(base, crate::workload::inference::decode_step_time(&m, 8, 8192, kv, &fast));
    let mut bw = p.clone();
    bw.tiers.local.media.bw *= 2.0;
    bw.tiers.pool.media.bw *= 2.0;
    let mb = improvement(base, crate::workload::inference::decode_step_time(&m, 8, 8192, kv, &bw));
    let mut lat = p.clone();
    for l in &mut lat.tiers.pool.links {
        l.latency /= 2.0;
    }
    let la = improvement(base, crate::workload::inference::decode_step_time(&m, 8, 8192, kv, &lat));
    // decode is capacity-hungry (KV): proxy importance between bw and latency
    vec![c, mb, 0.8 * mb, 0.3 * mb, la.max(0.3 * mb)]
}

fn rag_sensitivity() -> Vec<f64> {
    let cfg = RagConfig::recipe_demo();
    let p = Platform::composable_cxl();
    let base = run_rag(&cfg, &p).total();
    let mut fast = p.clone();
    fast.accel.flops *= 2.0;
    let c = improvement(base, run_rag(&cfg, &fast).total());
    let mut bw = p.clone();
    bw.tiers.pool.media.bw *= 2.0;
    for l in &mut bw.tiers.pool.links {
        l.bw *= 2.0;
    }
    let mb = improvement(base, run_rag(&cfg, &bw).total());
    let mut lat = p.clone();
    for l in &mut lat.tiers.pool.links {
        l.latency /= 2.0;
    }
    let la = improvement(base, run_rag(&cfg, &lat).total());
    // RAG leans on capacity (corpus residency) and latency
    vec![c, mb, mb.max(la), 0.5 * mb, la]
}

/// Fig 29 — topology trade-offs at growing endpoint counts.
pub fn fig29() -> Table {
    let mut rows = Vec::new();
    for n in [64usize, 256, 1024] {
        for (name, topo) in [
            ("multi-Clos", Topology::multi_clos(n, 32, 8)),
            ("3D-Torus", {
                let side = (n as f64).cbrt().round() as usize;
                Topology::torus3d(side, side, side)
            }),
            ("DragonFly", {
                let groups = (n as f64).sqrt().round() as usize;
                Topology::dragonfly(groups, n / groups.max(1))
            }),
        ] {
            rows.push(vec![
                format!("{n}"),
                name.into(),
                topo.switch_count().to_string(),
                format!("{:.2}", topo.mean_hops()),
                crate::fabric::switch::switches_required(topo.kind(), n, 64).to_string(),
            ]);
        }
    }
    Table {
        title: "Fig 29 — Clos vs 3D-Torus vs DragonFly scaling".into(),
        headers: vec!["endpoints", "topology", "switch nodes", "mean hops", "analytic switch count"],
        rows,
    }
}

/// Fig 41 — CXL-over-XLink supercluster fabric shapes.
pub fn fig41() -> Table {
    let mut rows = Vec::new();
    for shape in [SuperclusterTopology::MultiClos, SuperclusterTopology::Torus3D, SuperclusterTopology::DragonFly] {
        let clusters: Vec<XLinkCluster> =
            (0..6).map(|i| if i % 2 == 0 { XLinkCluster::nvl72() } else { XLinkCluster::ualink(64) }).collect();
        let mut sc = Supercluster::build(&clusters, shape, 4).with_bridge_cache(0.5);
        let intra = sc.transfer_accel((0, 0), (0, 1), 1 << 20, 0.0).unwrap();
        sc.fabric_mut().reset();
        let inter = sc.transfer_accel((0, 0), (5, 0), 1 << 20, 0.0).unwrap();
        sc.fabric_mut().reset();
        let tray = sc.transfer_to_tray((0, 0), 0, 1 << 20, 0.0).unwrap();
        rows.push(vec![
            format!("{shape:?}"),
            fmt_ns(intra.latency),
            fmt_ns(inter.latency),
            fmt_ns(tray.latency),
            format!("{}", inter.hops),
        ]);
    }
    Table {
        title: "Fig 41 — supercluster shapes (1 MiB transfers)".into(),
        headers: vec!["fabric shape", "intra-cluster", "inter-cluster", "to tier-2 tray", "inter hops"],
        rows,
    }
}

/// §3.4 — parallelization utilization ceilings and the 35–70% comm tax.
pub fn sec34() -> Table {
    let accel = AcceleratorSpec::b200();
    let paths = TrainingPaths {
        tp: conventional_path(HierarchyLevel::Rack),
        pp: conventional_path(HierarchyLevel::Rack),
        dp: conventional_path(HierarchyLevel::Row),
        ep: conventional_path(HierarchyLevel::Rack),
    };
    let mut rows = Vec::new();
    // DP's 35–40% ceiling is measured against the *optimized* NCCL path
    // (GPUDirect RDMA), not the staged conventional path.
    {
        let mut dp_paths = paths.clone();
        dp_paths.dp = crate::datacenter::hierarchy::CommPath {
            links: vec![
                LinkSpec::infiniband_ndr(),
                LinkSpec::infiniband_ndr(),
                LinkSpec::infiniband_ndr(),
            ],
            stack: crate::fabric::netstack::SoftwareStack::rdma_gpudirect(),
        };
        let cfg = TrainingConfig {
            model: ModelSpec::llama_70b(),
            plan: ParallelismPlan { dp: 512, tp: 1, pp: 1, ep: 1, microbatches: 1 },
            global_batch_tokens: 4 * 1024 * 1024,
            compute_efficiency: 0.55,
        };
        let r = simulate_step(&cfg, &accel, &dp_paths);
        rows.push(vec![
            "data parallel".into(),
            "512".into(),
            format!("{:.1}%", 100.0 * r.utilization()),
            format!("{:.1}%", 100.0 * r.comm_fraction()),
            "util 35-40%".into(),
        ]);
    }
    let cases: [(&str, ModelSpec, ParallelismPlan, &str); 3] = [
        (
            "pipeline parallel",
            ModelSpec::gpt3_175b(),
            ParallelismPlan { dp: 1, tp: 1, pp: 16, ep: 1, microbatches: 16 },
            "util ~50%",
        ),
        (
            "hybrid 4096 GPUs",
            ModelSpec::gpt3_175b(),
            ParallelismPlan { dp: 64, tp: 8, pp: 8, ep: 1, microbatches: 16 },
            "comm tax 35-70%",
        ),
        (
            "MoE + expert parallel",
            ModelSpec::moe_8x22b(),
            ParallelismPlan { dp: 8, tp: 8, pp: 4, ep: 8, microbatches: 8 },
            "comm tax 35-70%",
        ),
    ];
    for (name, model, plan, paper) in cases {
        let cfg = TrainingConfig { model, plan, global_batch_tokens: 4 * 1024 * 1024, compute_efficiency: 0.55 };
        let r = simulate_step(&cfg, &accel, &paths);
        rows.push(vec![
            name.into(),
            format!("{}", plan.gpus()),
            format!("{:.1}%", 100.0 * r.utilization()),
            format!("{:.1}%", 100.0 * r.comm_fraction()),
            paper.into(),
        ]);
    }
    Table {
        title: "§3.4 — parallelization utilization and communication tax".into(),
        headers: vec!["strategy", "gpus", "utilization", "comm fraction", "paper"],
        rows,
    }
}

/// §6.3 — memory-tier latency ladder and lightweight-CXL options.
pub fn sec63() -> Table {
    let t = TieredMemory::proposed(192 * GIB, 64 * 1024 * GIB);
    let conv = TieredMemory::conventional(192 * GIB);
    let b = 4096u64;
    let mut rows = vec![
        vec!["tier-1 local HBM".into(), fmt_ns(t.read(Tier::Local, b)), "~100 ns".into()],
        vec!["tier-1 peer (XLink)".into(), fmt_ns(t.read(Tier::ClusterPeer, b)), "<500 ns".into()],
        vec!["tier-2 CXL pool".into(), fmt_ns(t.read(Tier::Pool, b)), "tens-hundreds ns".into()],
        vec!["conventional remote (RDMA)".into(), fmt_ns(conv.read(Tier::Pool, b)), ">1 us".into()],
        vec!["storage path".into(), fmt_ns(t.read(Tier::Storage, b)), "ms to tens of s".into()],
    ];
    // lightweight stack complexity ladder
    for (name, stack) in [
        ("full CXL stack", CxlStack::full()),
        ("coherence-centric (tier-1)", CxlStack::coherence_centric()),
        ("capacity-oriented (tier-2)", CxlStack::capacity_oriented()),
        ("io-only staging", CxlStack::io_only()),
    ] {
        rows.push(vec![
            format!("controller complexity: {name}"),
            format!("{:.2} (rel)", stack.complexity()),
            "trimmed stacks cheaper".into(),
        ]);
    }
    Table {
        title: "§6.3 — memory tiers and lightweight CXL implementations (4 KiB reads)".into(),
        headers: vec!["path", "measured", "paper"],
        rows,
    }
}

/// Ablations over the design choices DESIGN.md calls out: bridge HBM
/// cache (Fig 43a), flit formats, PBR-vs-HBR under congestion and failure,
/// and KV-cache pooling during decode.
pub fn ablations() -> Table {
    use crate::fabric::routing::RoutingPolicy;
    use crate::fabric::Fabric;
    let mut rows: Vec<Vec<String>> = Vec::new();

    // (a) bridge HBM conversion cache (Fig 43a)
    {
        let clusters = [XLinkCluster::nvl72(), XLinkCluster::ualink(64)];
        let mut plain = Supercluster::build(&clusters, SuperclusterTopology::MultiClos, 2);
        let mut cached = Supercluster::build(&clusters, SuperclusterTopology::MultiClos, 2).with_bridge_cache(0.9);
        let a = plain.transfer_accel((0, 0), (1, 0), 4096, 0.0).unwrap().latency;
        let b = cached.transfer_accel((0, 0), (1, 0), 4096, 0.0).unwrap().latency;
        rows.push(vec![
            "bridge HBM cache (Fig 43a), 4 KiB inter-cluster".into(),
            format!("off: {}", fmt_ns(a)),
            format!("90% hits: {}", fmt_ns(b)),
            format!("-{:.0}%", 100.0 * (1.0 - b / a)),
        ]);
    }

    // (b) CXL flit format: HBR 68B vs PBR 256B on bulk transfers
    {
        let hbr = LinkSpec::cxl3_hbr_x16();
        let pbr = LinkSpec::cxl3_x16();
        let t_h = hbr.wire_time(1 << 26);
        let t_p = pbr.wire_time(1 << 26);
        rows.push(vec![
            "flit format, 64 MiB bulk".into(),
            format!("68B@32GT/s: {}", fmt_ns(t_h)),
            format!("256B@64GT/s: {}", fmt_ns(t_p)),
            format!("{:.2}x", t_h / t_p),
        ]);
    }

    // (c) routing under congestion: 72-endpoint Clos, hotspot traffic
    {
        let run = |policy| {
            let topo = Topology::single_clos(16, 4);
            let eps = topo.endpoints().to_vec();
            let mut f = Fabric::new(topo, LinkSpec::cxl3_x16(), policy);
            let mut done = 0.0f64;
            for i in 0..512 {
                let r = f.transfer(eps[i % 8], eps[8 + (i % 8)], 1 << 20, 0.0).unwrap();
                done = done.max(r.arrival);
            }
            done
        };
        let h = run(RoutingPolicy::Hbr);
        let p = run(RoutingPolicy::Pbr);
        rows.push(vec![
            "512×1MiB hotspot makespan".into(),
            format!("HBR: {}", fmt_ns(h)),
            format!("PBR: {}", fmt_ns(p)),
            format!("{:.2}x", h / p),
        ]);
    }

    // (d) routing under a failed switch plane
    {
        let survive = |policy| {
            let topo = Topology::single_clos(8, 2);
            let eps = topo.endpoints().to_vec();
            let mut f = Fabric::new(topo, LinkSpec::cxl3_x16(), policy);
            // fail every edge touching switch-plane node 0
            for e in 0..f.topology().edge_count() {
                let (a, b) = f.topology().edge(e);
                if a == 0 || b == 0 {
                    f.fail_edge(e);
                }
            }
            let ok = (0..8).filter(|&i| f.transfer(eps[i], eps[(i + 1) % 8], 64, 0.0).is_some()).count();
            ok
        };
        rows.push(vec![
            "pairs delivered after plane failure (of 8)".into(),
            format!("HBR: {}", survive(RoutingPolicy::Hbr)),
            format!("PBR: {}", survive(RoutingPolicy::Pbr)),
            "PBR reroutes".into(),
        ]);
    }

    // (e) KV placement during decode (the §4.3 pooling story)
    {
        let m = ModelSpec::llama_70b();
        let p = Platform::composable_cxl();
        let local = crate::workload::inference::decode_step_time(&m, 8, 8192, KvPlacement::Local, &p);
        let pooled =
            crate::workload::inference::decode_step_time(&m, 8, 8192, KvPlacement::Remote { remote_frac_pct: 50 }, &p);
        rows.push(vec![
            "decode step, 8×8k ctx (70B)".into(),
            format!("KV local: {}", fmt_ns(local)),
            format!("KV 50% pooled: {}", fmt_ns(pooled)),
            format!("+{:.0}% latency buys 2x batch capacity", 100.0 * (pooled / local - 1.0)),
        ]);
    }

    Table {
        title: "Ablations — design-choice sensitivity".into(),
        headers: vec!["ablation", "variant A", "variant B", "delta"],
        rows,
    }
}

/// Communication-tax ledger — the same traffic priced by the analytic
/// (idle-fabric) model and by the flow-level contention-aware simulator,
/// plus the per-link utilization telemetry the simulator emits. The spread
/// between the two columns *is* the paper's communication tax; the
/// analytic model is structurally blind to it.
pub fn comm_tax() -> Table {
    use crate::fabric::flow::{FabricSim, TrafficClass, Transfer};
    use crate::fabric::routing::RoutingPolicy;
    use crate::sim::Engine;
    use crate::workload::collectives::allreduce_alone_vs_shared;

    let mut rows: Vec<Vec<String>> = Vec::new();

    // (a) idle fabric: the flow model collapses to the analytic closed form
    {
        let sim = FabricSim::new(Topology::single_clos(8, 2), LinkSpec::cxl3_x16(), RoutingPolicy::Pbr);
        let eps = sim.endpoints();
        let bytes = 16 * (1u64 << 20);
        let est = sim.estimate(eps[0], eps[1], bytes).expect("route");
        let mut eng = Engine::new();
        let d = sim
            .transfer_sync(&mut eng, Transfer::new(eps[0], eps[1], bytes, TrafficClass::Parameter))
            .expect("transfer");
        rows.push(vec![
            "16 MiB transfer, idle Clos".into(),
            fmt_ns(est),
            fmt_ns(d.latency),
            format!("{:+.2}% (must be ~0)", 100.0 * (d.latency / est - 1.0)),
        ]);
    }

    // (b) one NVL72-style rack: ring all-reduce alone vs two concurrent
    let mk = || {
        let sim = FabricSim::new(Topology::star(8), LinkSpec::nvlink5_bundle(), RoutingPolicy::Hbr);
        let ranks = sim.endpoints();
        (sim, ranks)
    };
    let (alone, shared, collective_ledger) =
        allreduce_alone_vs_shared(mk, 1u64 << 26).expect("routable all-reduce");
    rows.push(vec![
        "ring all-reduce, 8 ranks x 64 MiB".into(),
        format!("alone: {}", fmt_ns(alone)),
        format!("2 concurrent: {}", fmt_ns(shared)),
        format!("{:.2}x tax", shared / alone),
    ]);

    // (c) the ledger rows for (b): where the tax landed, link by link
    {
        let ledger = &collective_ledger;
        rows.push(vec![
            "ledger: fabric totals".into(),
            format!("{} flows", ledger.flows),
            format!("{} payload", crate::benchkit::fmt_bytes(ledger.total_payload)),
            format!("mean util {:.0}%, peak {:.0}%", 100.0 * ledger.mean_utilization, 100.0 * ledger.peak_utilization),
        ]);
        rows.push(vec![
            "ledger: per-flow contention".into(),
            format!("p50 {}", fmt_ns(ledger.contention.percentile(50.0))),
            format!("p99 {}", fmt_ns(ledger.contention.percentile(99.0))),
            format!("max {}", fmt_ns(ledger.contention.max())),
        ]);
        for l in ledger.hottest(3) {
            rows.push(vec![
                format!("hot link #{} ({})", l.edge, l.link),
                format!("{} -> {}", l.src, l.dst),
                format!("util {:.0}%", 100.0 * l.utilization),
                format!("{} carried, peak {} flows", crate::benchkit::fmt_bytes(l.payload), l.peak_flows),
            ]);
        }
    }

    // (d) serving with KV/activation flows on the shared fabric
    {
        // bursty arrivals over 4 clusters sharing one 2-plane pool fabric:
        // concurrent KV prefetches outnumber the planes, so serving feels
        // real link queueing
        let cfg = crate::serve::ServeConfig {
            requests: 96,
            clusters: 4,
            arrival_mean: 50_000.0,
            kv: KvPlacement::Remote { remote_frac_pct: 80 },
            ..Default::default()
        };
        let plat = Platform::composable_cxl();
        // same compute model (local KV), no fabric — the contended run is
        // this plus real KV/activation flows on the shared Clos
        let baseline_cfg = crate::serve::ServeConfig { kv: KvPlacement::Local, ..cfg.clone() };
        let plain = crate::serve::simulate_serving(&baseline_cfg, &plat);
        let (contended, ledger) = crate::serve::simulate_serving_contended(&cfg, &plat);
        rows.push(vec![
            "serving p99 latency (96 reqs, 80% pooled KV)".into(),
            format!("no-fabric: {}", fmt_ns(plain.latency.percentile(99.0))),
            format!("contended: {}", fmt_ns(contended.latency.percentile(99.0))),
            format!(
                "fabric wait mean {}, flow contention p99 {}, KV traffic {}",
                fmt_ns(contended.fabric_wait.mean()),
                fmt_ns(ledger.contention.percentile(99.0)),
                crate::benchkit::fmt_bytes(ledger.class_bytes(crate::fabric::TrafficClass::KvCache))
            ),
        ]);

        // (e) both runs' ledgers folded through the coordinator's
        // telemetry registry — the stable per-run reporting path
        let mut tel = crate::coordinator::telemetry::Telemetry::new();
        tel.record_fabric("train.fabric", &collective_ledger);
        tel.record_fabric("serve.fabric", &ledger);
        rows.push(vec![
            "telemetry registry".into(),
            format!("train.fabric.flows {}", tel.counter("train.fabric.flows")),
            format!("serve.fabric.flows {}", tel.counter("serve.fabric.flows")),
            format!(
                "serve util peak {:.0}%, contention p99 {}",
                100.0 * tel.gauge_value("serve.fabric.util.peak").unwrap_or(0.0),
                fmt_ns(tel.gauge_value("serve.fabric.contention.p99_ns").unwrap_or(0.0))
            ),
        ]);
    }

    Table {
        title: "Comm-tax ledger — analytic vs flow-level contention".into(),
        headers: vec!["metric", "A", "B", "delta / telemetry"],
        rows,
    }
}

/// Memory-tax ledger — the §6.3 hierarchical-memory traffic (KV
/// spills/fetches, tier migrations, P/D KV handoff) priced by the analytic
/// tier model next to the event-driven hierarchy on the contended flow
/// fabric. Idle rows must agree (~0% delta — the closed-form parity
/// contract); contended rows show memory flows sharing pool links with
/// serving traffic, the half of the communication tax the tier math is
/// structurally blind to.
pub fn mem_tax() -> Table {
    use crate::coordinator::placement::PlacementPolicy;
    use crate::fabric::flow::{TrafficClass, Transfer};
    use crate::mem::hierarchy::{HierarchicalMemory, MemDone};
    use crate::sim::Engine;
    use std::cell::RefCell;
    use std::rc::Rc;

    let mut rows: Vec<Vec<String>> = Vec::new();

    // (a) closed-form parity: idle-fabric spill + fetch == analytic tiers
    {
        let tiers = TieredMemory::proposed(GIB, 16 * GIB);
        let hier = HierarchicalMemory::new(2, 0, tiers.clone());
        let bytes = 4u64 << 20;
        let mut eng = Engine::new();
        let done: Rc<RefCell<Option<MemDone>>> = Rc::new(RefCell::new(None));
        let d2 = done.clone();
        hier.write_new(&mut eng, 1, bytes, 0, TrafficClass::KvCache, move |_, d| *d2.borrow_mut() = Some(d));
        eng.run();
        let spill = done.borrow().expect("idle spill completes");
        let analytic_w = tiers.write(Tier::Pool, bytes);
        rows.push(vec![
            "4 MiB KV spill to pool, idle fabric".into(),
            fmt_ns(analytic_w),
            fmt_ns(spill.latency),
            format!("{:+.2}% (must be ~0)", 100.0 * (spill.latency / analytic_w - 1.0)),
        ]);
        let fetch = hier.read_sync(&mut eng, 1, TrafficClass::KvCache).expect("idle fetch completes");
        let analytic_r = tiers.read(Tier::Pool, bytes);
        rows.push(vec![
            "4 MiB KV fetch from pool, idle fabric".into(),
            fmt_ns(analytic_r),
            fmt_ns(fetch.latency),
            format!("{:+.2}% (must be ~0)", 100.0 * (fetch.latency / analytic_r - 1.0)),
        ]);
    }

    // (b) contended tiering: four accelerators spill + fetch against
    // serving activation flows on the same tray uplinks
    {
        let tiers = TieredMemory::proposed(GIB, 16 * GIB);
        let hier = HierarchicalMemory::new(4, 0, tiers);
        let bytes = 8u64 << 20;
        let mut eng = Engine::new();
        let fetches: Rc<RefCell<Vec<MemDone>>> = Rc::new(RefCell::new(Vec::new()));
        for r in 0..4u64 {
            // spill flows contend with the serving writebacks on the tray
            // ingress; each fetch starts only once its bytes have landed
            let (v, hier2) = (fetches.clone(), hier.clone());
            hier.write_new(&mut eng, r, bytes, r as usize, TrafficClass::KvCache, move |e, _| {
                let v2 = v.clone();
                hier2.read(e, r, TrafficClass::KvCache, move |_, d| v2.borrow_mut().push(d));
            });
        }
        // two concurrent serving batches write activations back to the
        // same pool tray — memory and serving flows share links
        let fab = hier.fabric().clone();
        for c in 0..2 {
            fab.submit(&mut eng, Transfer::new(hier.node(c), hier.pool_node(), 16 << 20, TrafficClass::Activation));
        }
        eng.run();
        let ds = fetches.borrow();
        let mut ideal = 0.0;
        let mut measured = 0.0;
        for d in ds.iter() {
            ideal += d.ideal;
            measured += d.latency;
        }
        let n = ds.len().max(1) as f64;
        rows.push(vec![
            "4 concurrent KV fetches, shared tray uplink".into(),
            format!("idle: {}", fmt_ns(ideal / n)),
            format!("contended: {}", fmt_ns(measured / n)),
            format!("{:.2}x tax", measured / ideal.max(1e-9)),
        ]);
        let ledger = fab.ledger();
        rows.push(vec![
            "ledger: traffic by class".into(),
            format!("kvcache {}", crate::benchkit::fmt_bytes(ledger.class_bytes(TrafficClass::KvCache))),
            format!("activation {}", crate::benchkit::fmt_bytes(ledger.class_bytes(TrafficClass::Activation))),
            format!("contention p99 {}", fmt_ns(ledger.contention.percentile(99.0))),
        ]);
        for l in ledger.hottest(2) {
            rows.push(vec![
                format!("hot link #{} ({})", l.edge, l.link),
                format!("{} -> {}", l.src, l.dst),
                format!("util {:.0}%", 100.0 * l.utilization),
                format!("{} carried, peak {} flows", crate::benchkit::fmt_bytes(l.payload), l.peak_flows),
            ]);
        }
        // the coordinator's stable reporting path
        let mut tel = crate::coordinator::telemetry::Telemetry::new();
        tel.record_fabric("mem.fabric", &ledger);
        tel.record_hierarchy("mem.hier", &hier.stats());
        rows.push(vec![
            "telemetry registry".into(),
            format!("mem.hier.spills {}", tel.counter("mem.hier.spills")),
            format!("mem.hier.fetches {}", tel.counter("mem.hier.fetches")),
            format!(
                "fabric util peak {:.0}%",
                100.0 * tel.gauge_value("mem.fabric.util.peak").unwrap_or(0.0)
            ),
        ]);
    }

    // (c) fabric-fed placement: migrations defer when the pool links are hot
    {
        let drive = |util: f64| {
            let mut p = PlacementPolicy::new(64 * (1 << 20));
            for id in 0..24 {
                p.register(id, 1 << 20);
            }
            for _ in 0..4 {
                for id in 0..24 {
                    p.touch(id, 30);
                }
                p.rebalance_fed(util);
            }
            (p.migrations, p.deferred)
        };
        let (idle_m, _) = drive(0.0);
        let (hot_m, hot_d) = drive(0.85);
        rows.push(vec![
            "placement migrations over 4 windows".into(),
            format!("idle fabric: {idle_m} applied"),
            format!("85% hot: {hot_m} applied"),
            format!("{hot_d} deferred to protect foreground flows"),
        ]);
    }

    // (d) P/D disaggregation's KV handoff as measured pool traffic
    {
        use crate::serve::pd::{simulate_pd_fabric, PdConfig};
        let cfg = PdConfig { requests: 48, arrival_mean: 8.0e6, ..Default::default() };
        let plat = Platform::composable_cxl();
        let (uni, _, _) = simulate_pd_fabric(&cfg, &plat, false);
        let (dis, ledger, _) = simulate_pd_fabric(&cfg, &plat, true);
        rows.push(vec![
            "P/D KV handoff (48 reqs, 7B-class)".into(),
            "unified: local handoff, 0 flows".into(),
            format!(
                "disagg: {} flows, {}",
                ledger.flows,
                crate::benchkit::fmt_bytes(ledger.class_bytes(TrafficClass::KvCache))
            ),
            format!(
                "handoff mean {}, ITL p99 {} vs {}",
                fmt_ns(dis.handoff.mean()),
                fmt_ns(dis.itl.percentile(99.0)),
                fmt_ns(uni.itl.percentile(99.0))
            ),
        ]);
    }

    Table {
        title: "Mem-tax ledger — hierarchical memory: analytic vs contended fabric".into(),
        headers: vec!["metric", "A", "B", "delta / telemetry"],
        rows,
    }
}

/// Supercluster-tax ledger — the §6.2 CXL-over-XLink supercluster priced
/// on the contended flow fabric: idle-fabric parity for the hierarchical
/// all-reduce (closed form vs measured), flat vs hierarchical all-reduce
/// across every Fig 41 fabric shape and two cluster counts (the
/// "reduce long-distance data transfers" claim as a measured inter-cluster
/// byte count), and multi-tenant serving whose KV/activation/sync flows
/// genuinely share bridge and spine links under a fabric-aware router.
pub fn supercluster_tax() -> Table {
    use crate::coordinator::telemetry::Telemetry;
    use crate::serve::supercluster::{simulate_supercluster, SuperServeConfig};
    use crate::workload::collectives::{
        flat_allreduce_contended, hierarchical_allreduce_contended, hierarchical_allreduce_ideal,
    };

    let mut rows: Vec<Vec<String>> = Vec::new();
    let shapes = [SuperclusterTopology::MultiClos, SuperclusterTopology::Torus3D, SuperclusterTopology::DragonFly];
    let bytes = 4u64 << 20; // 4 MiB gradient shard
    let mk = |shape, clusters: usize| {
        Supercluster::build_sim(&vec![XLinkCluster::ualink(8); clusters], shape, 2)
    };

    // (a) idle-fabric parity: the event-driven hierarchical all-reduce
    // reproduces its closed form on an empty supercluster
    {
        let scs = mk(SuperclusterTopology::MultiClos, 2);
        let ideal = hierarchical_allreduce_ideal(&scs, bytes).expect("routable supercluster");
        let measured = hierarchical_allreduce_contended(&scs, bytes).expect("hierarchical all-reduce completes");
        rows.push(vec![
            "hier all-reduce 2×8 MultiClos, idle".into(),
            fmt_ns(ideal),
            fmt_ns(measured),
            format!("{:+.2}% (must be ~0)", 100.0 * (measured / ideal - 1.0)),
        ]);
    }

    // (b) flat vs hierarchical: completion time and measured inter-cluster
    // (CXL) bytes, per shape × cluster count
    for shape in shapes {
        for clusters in [2usize, 4] {
            let flat_sc = mk(shape, clusters);
            let flat_t = flat_allreduce_contended(&flat_sc, bytes).expect("flat all-reduce completes");
            let flat_b = flat_sc.inter_cluster_payload();
            let hier_sc = mk(shape, clusters);
            let hier_t = hierarchical_allreduce_contended(&hier_sc, bytes).expect("hier all-reduce completes");
            let hier_b = hier_sc.inter_cluster_payload();
            rows.push(vec![
                format!("{shape:?} ×{clusters} clusters, 4 MiB all-reduce"),
                format!("flat: {} / {}", fmt_ns(flat_t), crate::benchkit::fmt_bytes(flat_b)),
                format!("hier: {} / {}", fmt_ns(hier_t), crate::benchkit::fmt_bytes(hier_b)),
                format!("{:.2}x fewer CXL bytes", flat_b as f64 / hier_b.max(1) as f64),
            ]);
        }
    }

    // (c) multi-tenant serving: relaxed vs flooded arrivals on the same
    // supercluster — the fabric wait and contention are measured outputs
    let plat = Platform::composable_cxl();
    let relaxed_cfg = SuperServeConfig { arrival_mean: 20.0e6, ..Default::default() };
    let flooded_cfg = SuperServeConfig { arrival_mean: 30_000.0, ..Default::default() };
    let (relaxed, _, _) = simulate_supercluster(&relaxed_cfg, &plat);
    let (flooded, ledger, _) = simulate_supercluster(&flooded_cfg, &plat);
    rows.push(vec![
        "3-tenant serving p99 (96 reqs, fabric-aware router)".into(),
        format!("relaxed: {}", fmt_ns(relaxed.latency.percentile(99.0))),
        format!("flooded: {}", fmt_ns(flooded.latency.percentile(99.0))),
        format!(
            "fabric wait mean {} vs {}",
            fmt_ns(relaxed.fabric_wait.mean()),
            fmt_ns(flooded.fabric_wait.mean())
        ),
    ]);
    rows.push(vec![
        "flooded serving ledger".into(),
        format!(
            "kv {} / act {}",
            crate::benchkit::fmt_bytes(ledger.class_bytes(crate::fabric::TrafficClass::KvCache)),
            crate::benchkit::fmt_bytes(ledger.class_bytes(crate::fabric::TrafficClass::Activation))
        ),
        format!(
            "sync {} ({} inter-cluster)",
            crate::benchkit::fmt_bytes(ledger.class_bytes(crate::fabric::TrafficClass::Collective)),
            crate::benchkit::fmt_bytes(flooded.inter_cluster_bytes)
        ),
        format!("flow contention p99 {}", fmt_ns(ledger.contention.percentile(99.0))),
    ]);
    for l in ledger.hottest(2) {
        rows.push(vec![
            format!("hot link #{} ({})", l.edge, l.link),
            format!("{} -> {}", l.src, l.dst),
            format!("util {:.0}%", 100.0 * l.utilization),
            format!("{} carried, peak {} flows", crate::benchkit::fmt_bytes(l.payload), l.peak_flows),
        ]);
    }

    // (d) the coordinator's stable reporting path
    let mut tel = Telemetry::new();
    tel.record_supercluster("sc.fabric", &ledger, flooded.inter_cluster_bytes);
    rows.push(vec![
        "telemetry registry".into(),
        format!("sc.fabric.flows {}", tel.counter("sc.fabric.flows")),
        format!("sc.fabric.intercluster_bytes {}", tel.counter("sc.fabric.intercluster_bytes")),
        format!(
            "util peak {:.0}%, contention p99 {}",
            100.0 * tel.gauge_value("sc.fabric.util.peak").unwrap_or(0.0),
            fmt_ns(tel.gauge_value("sc.fabric.contention.p99_ns").unwrap_or(0.0))
        ),
    ]);

    Table {
        title: "Supercluster tax — flat vs hierarchical collectives and multi-tenant serving (CXL-over-XLink)".into(),
        headers: vec!["metric", "A", "B", "delta / telemetry"],
        rows,
    }
}

/// Prefill/decode disaggregation (§4.3's reconfiguration story): TTFT and
/// inter-token latency under unified vs disaggregated engine pools.
pub fn pd_disagg() -> Table {
    use crate::serve::pd::{simulate_pd, PdConfig};
    let cfg = PdConfig { requests: 96, arrival_mean: 15.0e6, ..Default::default() };
    let p = Platform::composable_cxl();
    let unified = simulate_pd(&cfg, &p, false);
    let disagg = simulate_pd(&cfg, &p, true);
    let row = |name: &str, u: f64, d: f64| {
        vec![name.to_string(), fmt_ns(u), fmt_ns(d), format!("{:.2}x", u / d)]
    };
    // one sorted/sketched snapshot per summary instead of a cut per row
    let (u_ttft, d_ttft) = (unified.ttft.percentiles(), disagg.ttft.percentiles());
    let (u_itl, d_itl) = (unified.itl.percentiles(), disagg.itl.percentiles());
    Table {
        title: "§4.3 — prefill/decode disaggregation (96 reqs, 7B-class)".into(),
        headers: vec!["metric", "unified", "disaggregated", "gain"],
        rows: vec![
            row("TTFT p50", u_ttft.p50, d_ttft.p50),
            row("TTFT p99", u_ttft.p99, d_ttft.p99),
            row("inter-token p50", u_itl.p50, d_itl.p50),
            row("inter-token p99", u_itl.p99, d_itl.p99),
            row("makespan", unified.makespan, disagg.makespan),
        ],
    }
}

/// Train-tax ledger — the §3.4 parallelism tax as a *measured* output of
/// the event-driven 3D-parallel trainer on the contended supercluster:
/// idle-fabric parity against the analytic `simulate_step` closed form,
/// DP-ring self-contention and backward-overlap ablations, the three §3.4
/// parallelism mixes trained alone vs colocated with serving tenants
/// (step-time and comm-fraction inflation), the serving side's p99
/// inflation, and the per-axis byte attribution through telemetry.
pub fn train_tax() -> Table {
    use crate::coordinator::telemetry::Telemetry;
    use crate::serve::colocate::{simulate_colocate, ColocateConfig};
    use crate::workload::training::{
        hybrid_flow_mix, sec34_flow_mixes, simulate_step_flows, FlowTrainOptions, TrainAxis, TrainMapping,
    };

    let accel = AcceleratorSpec::b200();
    let plat = Platform::composable_cxl();
    let mut rows: Vec<Vec<String>> = Vec::new();

    let mixes = sec34_flow_mixes();
    let hybrid_cfg = hybrid_flow_mix().1;
    let hybrid = hybrid_cfg.plan;
    let shape = crate::datacenter::cluster::SuperclusterTopology::MultiClos;

    // (a) idle-fabric parity: the event-driven step reproduces the closed
    // form (same StepReport) on an empty supercluster
    {
        let map = TrainMapping::build(hybrid, shape, 1);
        let ideal = map.ideal_step(&hybrid_cfg, &accel).expect("routable mapping");
        let rep = simulate_step_flows(&map, &hybrid_cfg, &accel, FlowTrainOptions::parity()).expect("step completes");
        rows.push(vec![
            "hybrid 2x2x2 step, idle fabric".into(),
            fmt_ns(ideal.total()),
            fmt_ns(rep.step.total()),
            format!("{:+.2}% (must be ~0)", 100.0 * (rep.step.total() / ideal.total() - 1.0)),
        ]);

        // (b) what the closed form cannot see even alone: every (stage,
        // tp-rank) position runs its own DP ring, and the rings queue on
        // the shared bridges (the parity run doubles as the 1-ring
        // reference — the sim is deterministic)
        let map2 = TrainMapping::build(hybrid, shape, 1);
        let full = simulate_step_flows(&map2, &hybrid_cfg, &accel, FlowTrainOptions::full()).expect("completes");
        rows.push(vec![
            "DP gradient sync: 1 ring (closed form) vs 4 rings".into(),
            format!("1 ring: {}", fmt_ns(rep.step.dp_comm)),
            format!("4 rings: {}", fmt_ns(full.step.dp_comm)),
            format!("{:.2}x bridge self-contention", full.step.dp_comm / rep.step.dp_comm),
        ]);
        // (c) overlapping the sync with the pipeline drain claws time back
        let map3 = TrainMapping::build(hybrid, shape, 1);
        let over = simulate_step_flows(&map3, &hybrid_cfg, &accel, FlowTrainOptions::overlapped()).expect("completes");
        rows.push(vec![
            "DP sync overlap (on_done continuations)".into(),
            format!("serial: {}", fmt_ns(full.makespan)),
            format!("overlapped: {}", fmt_ns(over.makespan)),
            format!(
                "{} hidden under drain ({:.0}% of sync)",
                fmt_ns(over.overlap_saved),
                100.0 * over.overlap_efficiency()
            ),
        ]);
    }

    // (d) the three §3.4 parallelism mixes, trained alone vs colocated
    // with two flooded serving tenants on the same bridges and spines
    let mut hybrid_report = None;
    for (name, train, clusters, accels_per_cluster) in mixes {
        let cfg = ColocateConfig::flooded(train, clusters, accels_per_cluster);
        let r = simulate_colocate(&cfg, &plat).expect("plan fits the serving fabric");
        let scs = crate::serve::supercluster::build_scs(&cfg.serve);
        let analytic = TrainMapping::onto(&scs, cfg.train.plan)
            .and_then(|m| m.ideal_step(&cfg.train, &accel))
            .expect("routable mapping");
        let first = &r.train_colocated[0];
        rows.push(vec![
            format!("{name} ({} GPUs)", cfg.train.plan.gpus()),
            format!("analytic: {} / comm {:.1}%", fmt_ns(analytic.total()), 100.0 * analytic.comm_fraction()),
            format!(
                "colocated: {} / comm {:.1}%",
                fmt_ns(first.makespan),
                100.0 * first.step.comm_fraction()
            ),
            format!("{:.2}x step inflation vs alone", r.step_inflation()),
        ]);
        if name.starts_with("hybrid") {
            hybrid_report = Some(r);
        }
    }

    // (e) the serving side of the same hybrid colocation, plus ledger +
    // telemetry attribution
    if let Some(r) = hybrid_report {
        rows.push(vec![
            "serving tenants during the hybrid job".into(),
            format!("alone p99: {}", fmt_ns(r.serve_alone.latency.percentile(99.0))),
            format!("colocated p99: {}", fmt_ns(r.serve_colocated.latency.percentile(99.0))),
            format!(
                "{:.2}x latency inflation",
                r.serve_colocated.latency.percentile(99.0) / r.serve_alone.latency.percentile(99.0)
            ),
        ]);
        let first = &r.train_colocated[0];
        rows.push(vec![
            "per-axis training payload (ledger)".into(),
            format!(
                "dp {} / tp {}",
                crate::benchkit::fmt_bytes(first.axis_bytes(TrainAxis::Dp)),
                crate::benchkit::fmt_bytes(first.axis_bytes(TrainAxis::Tp))
            ),
            format!(
                "pp {} / ep {}",
                crate::benchkit::fmt_bytes(first.axis_bytes(TrainAxis::Pp)),
                crate::benchkit::fmt_bytes(first.axis_bytes(TrainAxis::Ep))
            ),
            format!(
                "tenants: kv {}",
                crate::benchkit::fmt_bytes(r.ledger.class_bytes(crate::fabric::TrafficClass::KvCache))
            ),
        ]);
        for l in r.ledger.hottest(2) {
            rows.push(vec![
                format!("hot link #{} ({})", l.edge, l.link),
                format!("{} -> {}", l.src, l.dst),
                format!("util {:.0}%", 100.0 * l.utilization),
                format!("{} carried, peak {} flows", crate::benchkit::fmt_bytes(l.payload), l.peak_flows),
            ]);
        }
        let mut tel = Telemetry::new();
        for step in &r.train_colocated {
            tel.record_training("train", step);
        }
        rows.push(vec![
            "telemetry registry".into(),
            format!("train.steps {}", tel.counter("train.steps")),
            format!("train.payload.dp {}", tel.counter("train.payload.dp")),
            format!(
                "comm frac peak {:.1}%, bubble {:.1}%",
                100.0 * tel.gauge_value("train.step.comm_fraction_peak").unwrap_or(0.0),
                100.0 * tel.gauge_value("train.step.bubble_fraction").unwrap_or(0.0)
            ),
        ]);
    }

    Table {
        title: "Train tax — event-driven 3D-parallel training: analytic vs measured, alone vs colocated with serving"
            .into(),
        headers: vec!["metric", "A", "B", "delta / telemetry"],
        rows,
    }
}

/// RAG-tax ledger — the Fig 33/34 retrieval pipeline priced by the
/// analytic closed forms next to the event-driven run on the contended
/// fabric: idle-fabric parity per phase (the <0.1% acceptance contract),
/// CXL-direct vs software-copy data movement (Fig 31's 21.1×), hot-node
/// promotion genuinely changing hop latency, and RAG alone vs colocated
/// with the flooded multi-tenant serving mix — the search-phase inflation
/// the analytic model is structurally blind to, as a ledger output.
pub fn rag_tax() -> Table {
    use crate::coordinator::telemetry::Telemetry;
    use crate::serve::rag_colocate::{simulate_rag_colocate, RagColocateConfig};
    use crate::workload::rag::{simulate_rag_flows, RagFlowOptions};

    let plat = Platform::composable_cxl();
    let mut rows: Vec<Vec<String>> = Vec::new();

    // (a) idle-fabric parity: the dependent-flow pipeline reproduces the
    // analytic RagReport per phase
    let parity = simulate_rag_flows(&RagConfig::flow_demo(), RagFlowOptions::parity(), &plat);
    let analytic = run_rag(&RagConfig::flow_demo(), &plat);
    rows.push(vec![
        "ANN search, idle fabric (flow demo)".into(),
        fmt_ns(analytic.search.total()),
        fmt_ns(parity.search.elapsed),
        format!("{:+.2}% (must be ~0)", 100.0 * (parity.search.elapsed / analytic.search.total() - 1.0)),
    ]);
    rows.push(vec![
        "LLM generation, idle fabric (flow demo)".into(),
        fmt_ns(analytic.generation.total()),
        fmt_ns(parity.generation.elapsed),
        format!("{:+.2}% (must be ~0)", 100.0 * (parity.generation.elapsed / analytic.generation.total() - 1.0)),
    ]);
    let g_parity = simulate_rag_flows(&RagConfig::graph_flow_demo(), RagFlowOptions::parity(), &plat);
    let g_analytic = run_rag(&RagConfig::graph_flow_demo(), &plat);
    rows.push(vec![
        "Graph-RAG end-to-end, idle fabric".into(),
        fmt_ns(g_analytic.total()),
        fmt_ns(g_parity.total()),
        format!("{:+.2}% (must be ~0)", 100.0 * (g_parity.total() / g_analytic.total() - 1.0)),
    ]);

    // (b) CXL-direct load vs software-copy staging: search-phase data
    // movement at paper scale (Fig 31's 21.1×)
    {
        let cfg = RagConfig::recipe_demo();
        let dm_cxl = cfg.search_data_movement(&plat);
        let dm_rdma = cfg.search_data_movement(&Platform::conventional_rdma());
        rows.push(vec![
            "search data movement (CXL-direct vs software-copy)".into(),
            crate::benchkit::fmt_bytes(dm_cxl),
            crate::benchkit::fmt_bytes(dm_rdma),
            format!("{:.1}x reduction (paper 21.1x)", dm_rdma as f64 / dm_cxl as f64),
        ]);
    }

    // (c) hot-node promotion: the corpus genuinely lives in the hierarchy,
    // so revisited graph nodes migrate into tier-1 and later hops skip the
    // fabric entirely
    {
        let cfg = RagConfig::flow_demo();
        let hot = simulate_rag_flows(
            &cfg,
            RagFlowOptions { local_budget: 64 * cfg.hop_bytes(), ..RagFlowOptions::promoting() },
            &plat,
        );
        rows.push(vec![
            "hot-node promotion (zipf walk)".into(),
            format!("cold: {}", fmt_ns(parity.search.elapsed)),
            format!("promoting: {} ({} promoted)", fmt_ns(hot.search.elapsed), hot.promotions),
            format!(
                "{} hops served from tier-1",
                crate::benchkit::fmt_bytes(hot.local_hop_bytes)
            ),
        ]);
    }

    // (d) RAG alone vs colocated with the flooded serving mix: the
    // retrieval tax from both sides over one ledger
    let r = simulate_rag_colocate(&RagColocateConfig::flooded(), &plat);
    rows.push(vec![
        "ANN search vs 3 flooded serving tenants".into(),
        format!("alone: {}", fmt_ns(r.rag_alone.search.elapsed)),
        format!("colocated: {}", fmt_ns(r.rag_colocated.search.elapsed)),
        format!("{:.2}x search inflation", r.search_inflation()),
    ]);
    rows.push(vec![
        "generation (remote-KV flows) same scenario".into(),
        format!("alone: {}", fmt_ns(r.rag_alone.generation.elapsed)),
        format!("colocated: {}", fmt_ns(r.rag_colocated.generation.elapsed)),
        format!(
            "{:.2}x inflation, KV-flow contention p99 {}",
            r.generation_inflation(),
            fmt_ns(r.rag_colocated.generation.contention.percentile(99.0))
        ),
    ]);
    rows.push(vec![
        "serving tenants during the retrieval job".into(),
        format!("alone p99: {}", fmt_ns(r.serve_alone.latency.percentile(99.0))),
        format!("colocated p99: {}", fmt_ns(r.serve_colocated.latency.percentile(99.0))),
        format!("{:.2}x latency inflation", r.serving_p99_inflation()),
    ]);
    rows.push(vec![
        "colocated ledger: traffic by class".into(),
        format!(
            "ann hops {}",
            crate::benchkit::fmt_bytes(r.ledger.class_bytes(crate::fabric::TrafficClass::Parameter))
        ),
        format!(
            "kv {} / act {}",
            crate::benchkit::fmt_bytes(r.ledger.class_bytes(crate::fabric::TrafficClass::KvCache)),
            crate::benchkit::fmt_bytes(r.ledger.class_bytes(crate::fabric::TrafficClass::Activation))
        ),
        format!("flow contention p99 {}", fmt_ns(r.ledger.contention.percentile(99.0))),
    ]);
    for l in r.ledger.hottest(2) {
        rows.push(vec![
            format!("hot link #{} ({})", l.edge, l.link),
            format!("{} -> {}", l.src, l.dst),
            format!("util {:.0}%", 100.0 * l.utilization),
            format!("{} carried, peak {} flows", crate::benchkit::fmt_bytes(l.payload), l.peak_flows),
        ]);
    }

    // (e) the coordinator's stable reporting path
    let mut tel = Telemetry::new();
    tel.record_rag("rag", &r.rag_colocated);
    rows.push(vec![
        "telemetry registry".into(),
        format!("rag.search.flows {}", tel.counter("rag.search.flows")),
        format!("rag.search.pool_bytes {}", tel.counter("rag.search.pool_bytes")),
        format!(
            "search inflation peak {:.2}x, contention p99 {}",
            tel.gauge_value("rag.search.inflation_peak").unwrap_or(0.0),
            fmt_ns(tel.gauge_value("rag.search.contention.p99_ns").unwrap_or(0.0))
        ),
    ]);

    Table {
        title: "RAG tax — event-driven retrieval on the contended fabric: analytic vs measured, alone vs colocated"
            .into(),
        headers: vec!["metric", "A", "B", "delta / telemetry"],
        rows,
    }
}

/// DLRM-tax ledger — the Fig 35 recommendation workload priced by the
/// analytic closed forms next to the event-driven run on the contended
/// fabric: idle-fabric parity per phase on both platforms (the <0.1%
/// acceptance contract, including the RDMA-staged init path), hot-shard
/// promotion genuinely changing gather latency, and DLRM alone vs
/// colocated with the flooded multi-tenant serving mix — the mixed
/// rec+LLM tenancy tax the analytic model is structurally blind to, as a
/// ledger output.
pub fn dlrm_tax() -> Table {
    use crate::coordinator::telemetry::Telemetry;
    use crate::serve::rec_colocate::{simulate_rec_colocate, RecColocateConfig};
    use crate::workload::dlrm::{simulate_dlrm_flows, DlrmFlowOptions};

    let plat = Platform::composable_cxl();
    let mut rows: Vec<Vec<String>> = Vec::new();

    // (a) idle-fabric parity: the routed table stream + gather flows
    // reproduce the analytic DlrmReport per phase — on the CXL-direct
    // write path and on the RDMA-staged baseline
    let parity = simulate_dlrm_flows(&DlrmConfig::flow_demo(), DlrmFlowOptions::parity(), &plat);
    let analytic = run_dlrm(&DlrmConfig::flow_demo(), &plat);
    rows.push(vec![
        "tensor init, idle fabric (flow demo)".into(),
        fmt_ns(analytic.init.total()),
        fmt_ns(parity.init.elapsed),
        format!("{:+.2}% (must be ~0)", 100.0 * (parity.init.elapsed / analytic.init.total() - 1.0)),
    ]);
    rows.push(vec![
        "inference gathers, idle fabric (flow demo)".into(),
        fmt_ns(analytic.inference.total()),
        fmt_ns(parity.inference.elapsed),
        format!("{:+.2}% (must be ~0)", 100.0 * (parity.inference.elapsed / analytic.inference.total() - 1.0)),
    ]);
    {
        let rdma = Platform::conventional_rdma();
        let r_parity = simulate_dlrm_flows(&DlrmConfig::flow_demo(), DlrmFlowOptions::parity(), &rdma);
        let r_analytic = run_dlrm(&DlrmConfig::flow_demo(), &rdma);
        rows.push(vec![
            "end-to-end, RDMA-staged baseline".into(),
            fmt_ns(r_analytic.total()),
            fmt_ns(r_parity.total()),
            format!("{:+.2}% (must be ~0)", 100.0 * (r_parity.total() / r_analytic.total() - 1.0)),
        ]);
    }

    // (b) the Fig 35 phase ratios, measured on the flow substrate
    {
        let cfg = DlrmConfig::flow_demo();
        let rdma = simulate_dlrm_flows(&cfg, DlrmFlowOptions::parity(), &Platform::conventional_rdma());
        rows.push(vec![
            "flow-measured speedup (init / inference)".into(),
            format!("init {:.2}x", rdma.init.elapsed / parity.init.elapsed),
            format!("inference {:.2}x", rdma.inference.elapsed / parity.inference.elapsed),
            "paper: 2.71x / 3.51x".into(),
        ]);
    }

    // (c) hot-shard promotion: the table genuinely lives in the
    // hierarchy, so revisited shards migrate into tier-1 and later
    // gathers skip the fabric entirely
    {
        let cfg = DlrmConfig { batches: 128, ..DlrmConfig::flow_demo() };
        let cold = simulate_dlrm_flows(&cfg, DlrmFlowOptions::parity(), &plat);
        let hot = simulate_dlrm_flows(&cfg, DlrmFlowOptions::promoting(), &plat);
        rows.push(vec![
            "hot-shard promotion (zipf batch stream)".into(),
            format!("cold: {}", fmt_ns(cold.inference.elapsed)),
            format!("promoting: {} ({} promoted)", fmt_ns(hot.inference.elapsed), hot.promotions),
            format!("{} gathers served from tier-1", crate::benchkit::fmt_bytes(hot.local_gather_bytes)),
        ]);
    }

    // (d) DLRM alone vs colocated with the flooded serving mix: the mixed
    // rec+LLM tenancy tax from both sides over one ledger
    let r = simulate_rec_colocate(&RecColocateConfig::flooded(), &plat);
    rows.push(vec![
        "table init stream vs 3 flooded serving tenants".into(),
        format!("alone: {}", fmt_ns(r.dlrm_alone.init.elapsed)),
        format!("colocated: {}", fmt_ns(r.dlrm_colocated.init.elapsed)),
        format!("{:.2}x init inflation", r.init_inflation()),
    ]);
    rows.push(vec![
        "embedding gathers same scenario".into(),
        format!("alone: {}", fmt_ns(r.dlrm_alone.inference.elapsed)),
        format!("colocated: {}", fmt_ns(r.dlrm_colocated.inference.elapsed)),
        format!(
            "{:.2}x inflation, gather contention p99 {}",
            r.inference_inflation(),
            fmt_ns(r.dlrm_colocated.inference.contention.percentile(99.0))
        ),
    ]);
    rows.push(vec![
        "serving tenants during the recommendation job".into(),
        format!("alone p99: {}", fmt_ns(r.serve_alone.latency.percentile(99.0))),
        format!("colocated p99: {}", fmt_ns(r.serve_colocated.latency.percentile(99.0))),
        format!("{:.2}x latency inflation", r.serving_p99_inflation()),
    ]);
    rows.push(vec![
        "colocated ledger: traffic by class".into(),
        format!(
            "table+gathers {}",
            crate::benchkit::fmt_bytes(r.ledger.class_bytes(crate::fabric::TrafficClass::Parameter))
        ),
        format!(
            "kv {} / act {}",
            crate::benchkit::fmt_bytes(r.ledger.class_bytes(crate::fabric::TrafficClass::KvCache)),
            crate::benchkit::fmt_bytes(r.ledger.class_bytes(crate::fabric::TrafficClass::Activation))
        ),
        format!("flow contention p99 {}", fmt_ns(r.ledger.contention.percentile(99.0))),
    ]);
    for l in r.ledger.hottest(2) {
        rows.push(vec![
            format!("hot link #{} ({})", l.edge, l.link),
            format!("{} -> {}", l.src, l.dst),
            format!("util {:.0}%", 100.0 * l.utilization),
            format!("{} carried, peak {} flows", crate::benchkit::fmt_bytes(l.payload), l.peak_flows),
        ]);
    }

    // (e) the coordinator's stable reporting path
    let mut tel = Telemetry::new();
    tel.record_dlrm("dlrm", &r.dlrm_colocated);
    rows.push(vec![
        "telemetry registry".into(),
        format!("dlrm.gather.flows {}", tel.counter("dlrm.gather.flows")),
        format!("dlrm.gather.pool_bytes {}", tel.counter("dlrm.gather.pool_bytes")),
        format!(
            "init inflation peak {:.2}x, contention p99 {}",
            tel.gauge_value("dlrm.init.inflation_peak").unwrap_or(0.0),
            fmt_ns(tel.gauge_value("dlrm.init.contention.p99_ns").unwrap_or(0.0))
        ),
    ]);

    Table {
        title: "DLRM tax — event-driven recommendation on the contended fabric: analytic vs measured, alone vs colocated"
            .into(),
        headers: vec!["metric", "A", "B", "delta / telemetry"],
        rows,
    }
}

/// Scenario tax — open-loop serving at scale: the deterministic scenario
/// generator (seeded Zipf tenancy over a modeled million-user population,
/// rate-curve-shaped Poisson arrivals) sweeps offered load over the
/// contended supercluster and reports the p50/p99/p999 latency-vs-load
/// hockey stick next to the communication-tax ledger at each point —
/// the open-loop picture the closed-loop serving mixes cannot show.
pub fn scenario_tax() -> Table {
    scenario_tax_on(crate::scenario::ScenarioTopology::default())
}

/// [`scenario_tax`] on a caller-chosen fabric — the CLI's `--topology`,
/// `--clusters` and `--accels` flags land here.
pub fn scenario_tax_on(topology: crate::scenario::ScenarioTopology) -> Table {
    use crate::scenario::{run_scenario, sweep_load, RateCurve, ScenarioConfig};

    let cfg = ScenarioConfig { requests: 600, rps: 2_000.0, topology, ..Default::default() };
    let plat = Platform::composable_cxl();
    let mut rows: Vec<Vec<String>> = Vec::new();
    rows.push(vec![
        format!(
            "{:?} ×{} clusters × {} accels, {} trays",
            topology.shape, topology.clusters, topology.accels_per_cluster, topology.mem_trays
        ),
        format!("{} tenants (zipf s={})", cfg.tenants, cfg.zipf_s),
        format!("{} modeled users", cfg.users),
        format!("{} reqs/point, batch ≤{} or {}", cfg.requests, cfg.max_batch, fmt_ns(cfg.max_wait)),
    ]);

    // (a) the latency-vs-offered-load curve: each point an independent
    // deterministic run at rps × multiplier
    let points = sweep_load(&cfg, &plat, &[0.25, 1.0, 4.0, 16.0]);
    for p in &points {
        let r = &p.report;
        let pct = r.latency.percentiles();
        rows.push(vec![
            format!("load ×{:<5} ({:.0} rps offered)", p.multiplier, r.offered_rps),
            format!("p50 {} / p99 {} / p999 {}", fmt_ns(pct.p50), fmt_ns(pct.p99), fmt_ns(pct.p999)),
            format!("achieved {:.0} rps", r.achieved_rps),
            format!("queue peak {}, mean batch {:.1}", r.queue_peak, r.batch_sizes.mean()),
        ]);
    }

    // (b) arrival shaping: the same offered volume, flat vs bursty — the
    // tail pays for the bursts even at equal mean load
    let flat = &points[1].report;
    let bursty_cfg = ScenarioConfig {
        curve: RateCurve::Bursty { mult: 8.0, duty: 0.1, period: 50.0e6 },
        ..cfg.clone()
    };
    let (bursty, _, _) = run_scenario(&bursty_cfg, &plat);
    rows.push(vec![
        "burst sensitivity at ×1 load".into(),
        format!("flat p999 {}", fmt_ns(flat.latency.percentiles().p999)),
        format!("bursty p999 {}", fmt_ns(bursty.latency.percentiles().p999)),
        format!("queue peak {} vs {}", flat.queue_peak, bursty.queue_peak),
    ]);

    // (c) the tax ledger where it hurts: the most-loaded point
    let last = points.last().expect("non-empty sweep");
    let ledger = &last.ledger;
    rows.push(vec![
        format!("ledger at ×{} load", last.multiplier),
        format!(
            "kv {} / act {}",
            crate::benchkit::fmt_bytes(ledger.class_bytes(crate::fabric::TrafficClass::KvCache)),
            crate::benchkit::fmt_bytes(ledger.class_bytes(crate::fabric::TrafficClass::Activation))
        ),
        format!(
            "sync {} ({} inter-cluster)",
            crate::benchkit::fmt_bytes(ledger.class_bytes(crate::fabric::TrafficClass::Collective)),
            crate::benchkit::fmt_bytes(last.report.inter_cluster_bytes)
        ),
        format!("flow contention p99 {}", fmt_ns(ledger.contention.percentiles().p99)),
    ]);
    for l in ledger.hottest(2) {
        rows.push(vec![
            format!("hot link #{} ({})", l.edge, l.link),
            format!("{} -> {}", l.src, l.dst),
            format!("util {:.0}%", 100.0 * l.utilization),
            format!("{} carried, peak {} flows", crate::benchkit::fmt_bytes(l.payload), l.peak_flows),
        ]);
    }

    Table {
        title: "Scenario tax — open-loop serving: latency vs offered load on the contended supercluster".into(),
        headers: vec!["metric", "A", "B", "delta / telemetry"],
        rows,
    }
}

/// Experiment driver function type (one per paper table/figure).
pub type TableFn = fn() -> Table;

/// The single source of truth binding experiment ids to drivers, in paper
/// order. [`all_tables`] and the CLI (`report --exp`, `list`) both derive
/// from this, so adding a table can never silently desync them (the
/// consistency test in `tests/integration_experiments.rs` locks it down).
pub fn registry() -> Vec<(&'static str, TableFn)> {
    vec![
        ("fig21", fig21 as TableFn),
        ("fig22", fig22),
        ("table1", table1),
        ("table2", table2),
        ("fig29", fig29),
        ("fig31", fig31),
        ("fig33", fig33),
        ("fig34", fig34),
        ("fig35", fig35),
        ("fig36", fig36),
        ("fig37", fig37),
        ("table3", table3),
        ("fig41", fig41),
        ("sec34", sec34),
        ("sec63", sec63),
        ("ablations", ablations),
        ("pd-disagg", pd_disagg),
        ("comm-tax", comm_tax),
        ("mem-tax", mem_tax),
        ("supercluster-tax", supercluster_tax),
        ("train-tax", train_tax),
        ("rag-tax", rag_tax),
        ("dlrm-tax", dlrm_tax),
        ("scenario-tax", scenario_tax),
    ]
}

/// Run one experiment by its CLI id.
pub fn by_id(id: &str) -> Option<Table> {
    registry().into_iter().find(|(name, _)| *name == id).map(|(_, f)| f())
}

/// All tables, in registry (paper) order.
pub fn all_tables() -> Vec<Table> {
    registry().into_iter().map(|(_, f)| f()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig31_rows_within_paper_shape() {
        let t = fig31();
        assert_eq!(t.rows.len(), 7);
        // every measured ratio must exceed 1 (CXL wins everywhere in Fig 31)
        for row in &t.rows {
            let measured: f64 = row[2].trim_end_matches('x').parse().unwrap();
            assert!(measured > 1.0, "{}: {measured}", row[0]);
        }
    }

    #[test]
    fn sec34_utilization_bands() {
        let t = sec34();
        let dp_util: f64 = t.rows[0][2].trim_end_matches('%').parse().unwrap();
        assert!((30.0..=45.0).contains(&dp_util), "dp util={dp_util}");
        let pp_util: f64 = t.rows[1][2].trim_end_matches('%').parse().unwrap();
        assert!((40.0..=60.0).contains(&pp_util), "pp util={pp_util}");
        let hybrid_comm: f64 = t.rows[2][3].trim_end_matches('%').parse().unwrap();
        assert!((35.0..=70.0).contains(&hybrid_comm), "hybrid comm={hybrid_comm}");
    }

    #[test]
    fn sec63_ladder_is_monotone() {
        let t = sec63();
        let parse = |s: &str| -> f64 {
            // fmt_ns output back to ns
            let parts: Vec<&str> = s.split_whitespace().collect();
            let v: f64 = parts[0].parse().unwrap();
            match parts[1] {
                "ns" => v,
                "us" => v * 1e3,
                "ms" => v * 1e6,
                "s" => v * 1e9,
                _ => panic!("unit"),
            }
        };
        let local = parse(&t.rows[0][1]);
        let peer = parse(&t.rows[1][1]);
        let pool = parse(&t.rows[2][1]);
        let rdma = parse(&t.rows[3][1]);
        let storage = parse(&t.rows[4][1]);
        assert!(local < peer && peer < pool && pool < rdma && rdma < storage);
    }

    #[test]
    fn all_tables_render() {
        for t in all_tables() {
            assert!(!t.rows.is_empty(), "{} empty", t.title);
            let md = t.markdown();
            assert!(md.contains("###"));
        }
    }

    #[test]
    fn comm_tax_idle_matches_and_contention_taxes() {
        let t = comm_tax();
        // idle fabric: flow model within 1% of the analytic estimate
        let delta: f64 = t.rows[0][3].split('%').next().unwrap().parse().unwrap();
        assert!(delta.abs() < 1.0, "idle delta={delta}%");
        // two concurrent collectives must pay a visible tax
        let tax: f64 = t.rows[1][3].split('x').next().unwrap().parse().unwrap();
        assert!(tax > 1.2, "tax={tax}");
        // per-link telemetry rows exist
        assert!(t.rows.iter().any(|r| r[0].starts_with("hot link")));
    }

    #[test]
    fn mem_tax_idle_parity_and_contended_sharing() {
        let t = mem_tax();
        // idle hierarchy rows reproduce the analytic tier math within 1%
        for row in &t.rows[..2] {
            let delta: f64 = row[3].split('%').next().unwrap().parse().unwrap();
            assert!(delta.abs() < 1.0, "{}: idle delta={delta}%", row[0]);
        }
        // contended fetches pay a visible tax sharing links with serving
        let tax: f64 = t.rows[2][3].split('x').next().unwrap().parse().unwrap();
        assert!(tax > 1.2, "tax={tax}");
        // the ledger attributes both memory and serving traffic
        assert!(t.rows[3][1].starts_with("kvcache"));
        assert!(t.rows[3][2].starts_with("activation"));
        assert!(t.rows.iter().any(|r| r[0].starts_with("hot link")));
    }

    #[test]
    fn supercluster_tax_parity_and_byte_reduction() {
        let t = supercluster_tax();
        // idle-fabric parity: measured hierarchical all-reduce within 1%
        let delta: f64 = t.rows[0][3].split('%').next().unwrap().parse().unwrap();
        assert!(delta.abs() < 1.0, "idle parity delta={delta}%");
        // every shape × cluster-count row: hierarchical moves strictly
        // fewer inter-cluster bytes (reduction factor > 1)
        let reduction_rows: Vec<&Vec<String>> =
            t.rows.iter().filter(|r| r[3].ends_with("fewer CXL bytes")).collect();
        assert_eq!(reduction_rows.len(), 6, "3 shapes × 2 cluster counts");
        for row in reduction_rows {
            let f: f64 = row[3].split('x').next().unwrap().parse().unwrap();
            assert!(f > 1.0, "{}: reduction {f} must exceed 1", row[0]);
        }
        // serving + ledger + telemetry rows are present
        assert!(t.rows.iter().any(|r| r[0].starts_with("3-tenant serving")));
        assert!(t.rows.iter().any(|r| r[0].starts_with("hot link")));
        assert!(t.rows.iter().any(|r| r[0] == "telemetry registry"));
    }

    #[test]
    fn registry_ids_unique_and_resolvable() {
        let reg = registry();
        let mut ids: Vec<_> = reg.iter().map(|(n, _)| *n).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), reg.len(), "duplicate experiment ids");
        assert!(by_id("train-tax").is_some());
        assert!(by_id("fig21").is_some());
        assert!(by_id("nope").is_none());
    }

    #[test]
    fn train_tax_parity_and_colocation_inflation() {
        let t = train_tax();
        // idle-fabric parity: the event-driven step within 0.1% of the
        // analytic closed form (the acceptance threshold)
        let delta: f64 = t.rows[0][3].split('%').next().unwrap().parse().unwrap();
        assert!(delta.abs() < 0.1, "idle parity delta={delta}%");
        // concurrent DP rings self-contend on the bridges
        let selfc: f64 = t.rows[1][3].split('x').next().unwrap().parse().unwrap();
        assert!(selfc > 1.0, "self-contention={selfc}");
        // all three §3.4 mixes: colocation inflates the step
        let mix_rows: Vec<&Vec<String>> =
            t.rows.iter().filter(|r| r[3].ends_with("step inflation vs alone")).collect();
        assert_eq!(mix_rows.len(), 3, "3 parallelism mixes");
        for row in mix_rows {
            let f: f64 = row[3].split('x').next().unwrap().parse().unwrap();
            assert!(f > 1.0, "{}: inflation {f} must exceed 1", row[0]);
        }
        // serving-side inflation + telemetry rows are present
        assert!(t.rows.iter().any(|r| r[0].starts_with("serving tenants")));
        assert!(t.rows.iter().any(|r| r[0].starts_with("hot link")));
        assert!(t.rows.iter().any(|r| r[0] == "telemetry registry"));
    }

    #[test]
    fn rag_tax_parity_and_colocation_inflation() {
        let t = rag_tax();
        // idle-fabric parity per phase: the event-driven pipeline within
        // 0.1% of the analytic closed forms (the acceptance threshold)
        for row in &t.rows[..3] {
            let delta: f64 = row[3].split('%').next().unwrap().parse().unwrap();
            assert!(delta.abs() < 0.1, "{}: idle parity delta={delta}%", row[0]);
        }
        // the colocated search phase pays a strictly positive tax
        let search_row = t.rows.iter().find(|r| r[3].ends_with("search inflation")).expect("search row");
        let f: f64 = search_row[3].split('x').next().unwrap().parse().unwrap();
        assert!(f > 1.0, "search inflation {f} must exceed 1");
        // serving pays too, and the ledger/telemetry rows are present
        assert!(t.rows.iter().any(|r| r[0].starts_with("serving tenants")));
        assert!(t.rows.iter().any(|r| r[0].starts_with("hot link")));
        assert!(t.rows.iter().any(|r| r[0] == "telemetry registry"));
    }

    #[test]
    fn dlrm_tax_parity_and_colocation_inflation() {
        let t = dlrm_tax();
        // idle-fabric parity per phase and platform: the routed run
        // within 0.1% of the analytic closed forms (the acceptance
        // threshold)
        for row in &t.rows[..3] {
            let delta: f64 = row[3].split('%').next().unwrap().parse().unwrap();
            assert!(delta.abs() < 0.1, "{}: idle parity delta={delta}%", row[0]);
        }
        // the colocated init stream pays a strictly positive tax
        let init_row = t.rows.iter().find(|r| r[3].ends_with("init inflation")).expect("init row");
        let f: f64 = init_row[3].split('x').next().unwrap().parse().unwrap();
        assert!(f > 1.0, "init inflation {f} must exceed 1");
        // serving pays too, and the ledger/telemetry rows are present
        assert!(t.rows.iter().any(|r| r[0].starts_with("serving tenants")));
        assert!(t.rows.iter().any(|r| r[0].starts_with("hot link")));
        assert!(t.rows.iter().any(|r| r[0] == "telemetry registry"));
    }

    #[test]
    fn fig29_direct_networks_use_more_switches() {
        let t = fig29();
        // at n=1024: multi-Clos uses far fewer switch nodes than torus
        let clos: usize = t.rows[6][2].parse().unwrap();
        let torus: usize = t.rows[7][2].parse().unwrap();
        assert!(clos < torus, "clos={clos} torus={torus}");
    }

    #[test]
    fn fig41_intra_faster_than_inter() {
        let t = fig41();
        for row in &t.rows {
            // crude parse: compare formatted strings via re-parse
            let parse = |s: &str| -> f64 {
                let parts: Vec<&str> = s.split_whitespace().collect();
                let v: f64 = parts[0].parse().unwrap();
                match parts[1] {
                    "ns" => v,
                    "us" => v * 1e3,
                    "ms" => v * 1e6,
                    _ => v * 1e9,
                }
            };
            assert!(parse(&row[1]) < parse(&row[2]), "{row:?}");
        }
    }
}
