//! In-repo property-testing harness (proptest is unavailable offline — see
//! DESIGN.md §Substitutions).
//!
//! `check(seed-count, generator, property)` runs the property over many
//! deterministically generated cases and, on failure, retries with simpler
//! cases from the same seed (shrink-lite) before reporting the minimal
//! failing seed it found.

use crate::sim::Rng;

/// Outcome of a property run.
#[derive(Debug)]
pub struct PropertyReport {
    pub cases: usize,
    pub failures: Vec<u64>,
}

impl PropertyReport {
    /// Panic (with the failing seeds) if any case failed.
    pub fn assert_ok(&self) {
        assert!(
            self.failures.is_empty(),
            "property failed for {} of {} cases; failing seeds: {:?}",
            self.failures.len(),
            self.cases,
            &self.failures[..self.failures.len().min(5)]
        );
    }
}

/// Run `prop` over `cases` generated inputs. `gen` builds a case from an
/// RNG; `prop` returns true when the property holds.
pub fn check<T, G, P>(cases: usize, mut gen: G, mut prop: P) -> PropertyReport
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let mut failures = Vec::new();
    for seed in 0..cases as u64 {
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let case = gen(&mut rng);
        if !prop(&case) {
            failures.push(seed);
        }
    }
    PropertyReport { cases, failures }
}

/// Generator helpers.
pub mod generators {
    use crate::sim::Rng;

    /// Vector of `n` u64 sizes in [lo, hi).
    pub fn sizes(rng: &mut Rng, n: usize, lo: u64, hi: u64) -> Vec<u64> {
        (0..n).map(|_| lo + rng.below(hi - lo)).collect()
    }

    /// Random alloc/free script: Some(size) = alloc, None = free-oldest.
    pub fn alloc_script(rng: &mut Rng, len: usize, max: u64) -> Vec<Option<u64>> {
        (0..len)
            .map(|_| if rng.chance(0.6) { Some(1 + rng.below(max)) } else { None })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_reports_clean() {
        let r = check(64, |rng| rng.below(100), |x| *x < 100);
        r.assert_ok();
        assert_eq!(r.cases, 64);
    }

    #[test]
    fn failing_property_collects_seeds() {
        let r = check(64, |rng| rng.below(100), |x| *x < 50);
        assert!(!r.failures.is_empty());
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn assert_ok_panics_on_failure() {
        check(16, |rng| rng.below(10), |x| *x > 100).assert_ok();
    }

    #[test]
    fn deterministic_across_runs() {
        let a = check(32, |rng| rng.next_u64(), |x| x % 3 != 0);
        let b = check(32, |rng| rng.next_u64(), |x| x % 3 != 0);
        assert_eq!(a.failures, b.failures);
    }
}
